"""End-to-end driver: train the paper's FCNN (reduced NN1) on the synthetic
fashion-mnist-shaped dataset for a few hundred steps, with the per-layer
parallelism degrees chosen by the ONoC planner and realized as JAX
shardings.

  PYTHONPATH=src python examples/train_fcnn_onoc.py [--steps 300]

With ``--program N`` the planner's schedule is *executed* instead of just
priced: the plan is compiled to a static RUN/SEND/RECV/FREE period program
(exec/program.py), cross-checked against core.simulator.simulate_epoch,
and interpreted under shard_map on an N-device CPU ring (exec/runtime.py):

  PYTHONPATH=src python examples/train_fcnn_onoc.py --program 8 --steps 100
"""

import argparse
import sys
import time

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kernel", default=None,
                    choices=["ref", "pallas", "pallas_interpret"],
                    help="force the fcnn_layer dispatch mode (default: "
                         "fused Pallas fwd+bwd on TPU, jnp oracle elsewhere)")
    ap.add_argument("--program", type=int, default=0, metavar="N",
                    help="compile the plan to a period program and execute "
                         "it under shard_map on an N-device CPU ring")
    ap.add_argument("--strategy", default="orrm",
                    choices=["fm", "rrm", "orrm"],
                    help="core mapping strategy (program mode)")
    args = ap.parse_args()

    if args.program:
        # must run before any other jax backend touch (forces N CPU devices)
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(args.program)
    else:
        mesh = None

    import jax
    import jax.numpy as jnp

    from repro.core.onoc_model import FCNNWorkload, ONoCConfig
    from repro.core.planner import plan_fcnn
    from repro.data import Batcher, fcnn_classification_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.models import fcnn
    from repro.optim import adam, linear_warmup_cosine

    # reduced NN1 (784-1000-500-10 -> 784-256-128-10) so CPU runs fast
    sizes = [784, 256, 128, 10]
    workload = FCNNWorkload(sizes, batch_size=args.batch)
    onoc = ONoCConfig(m=1000, lambda_max=64)

    if args.program:
        _run_program_mode(args, workload, onoc, mesh)
        return

    mesh = make_host_mesh()
    plan = plan_fcnn(workload, onoc, dict(mesh.shape), strategy="orrm")
    print("ONoC plan (per layer): "
          + ", ".join(f"L{p.period}: m*={p.onoc_cores} -> degree {p.degree}"
                      for p in plan.periods))

    key = jax.random.PRNGKey(0)
    params = fcnn.init(key, sizes)
    opt = adam(linear_warmup_cosine(3e-3, 20, args.steps))
    opt_state = opt.init(params)

    x, y = fcnn_classification_dataset(4096, input_dim=sizes[0], seed=0)
    batches = Batcher({"x": x, "y": y}, batch_size=args.batch, mesh=mesh)

    @jax.jit
    def step(params, opt_state, batch, i):
        loss, grads = jax.value_and_grad(
            lambda p, b: fcnn.loss_fn(p, b, kernel_mode=args.kernel)
        )(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, loss

    t0 = time.time()
    with mesh:
        for i in range(args.steps):
            batch = next(batches)
            params, opt_state, loss = step(params, opt_state, batch, i)
            if i % 50 == 0 or i == args.steps - 1:
                acc = fcnn.accuracy(params, jnp.asarray(x[:1024]),
                                    jnp.asarray(y[:1024]),
                                    kernel_mode=args.kernel)
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"acc {float(acc):.3f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({1e3 * dt / args.steps:.1f} ms/step)")
    final_acc = float(fcnn.accuracy(params, jnp.asarray(x), jnp.asarray(y),
                                    kernel_mode=args.kernel))
    print(f"final train accuracy: {final_acc:.3f}")
    assert final_acc > 0.8, "training failed to learn"


def _run_program_mode(args, workload, onoc, mesh) -> None:
    """Compile the plan to a RUN/SEND/RECV/FREE program, cross-check its
    cost annotations against the simulator, and train through it."""
    import jax
    import jax.numpy as jnp

    from repro.core.planner import plan_fcnn, ring_mesh_axes
    from repro.core.simulator import simulate_epoch
    from repro.data import fcnn_classification_dataset
    from repro.exec import compile_program
    from repro.exec.runtime import build_train_step
    from repro.models import fcnn
    from repro.optim import adam, linear_warmup_cosine
    from repro.parallel.sharding import replicate

    n = args.program
    sizes = list(workload.layer_sizes)
    plan = plan_fcnn(workload, onoc, ring_mesh_axes(n),
                     strategy=args.strategy)
    prog = compile_program(plan, workload, onoc, n)
    print(f"compiled {args.strategy.upper()} program: "
          f"{len(prog.instructions)} instructions over {2 * prog.l} periods "
          f"on a {n}-device ring")
    for i in prog.instructions:
        extra = (f" layer={i.layer} {i.phase} m*={i.onoc_cores} "
                 f"degree={i.degree}" if i.opcode.value == "run" else "")
        print(f"  P{i.period:>2} {i.opcode.value.upper():<4} "
              f"devices={list(i.devices)} cost={i.cost_s:.3e}s{extra}")

    trace = simulate_epoch(workload, onoc, mapping=plan.mapping)
    assert prog.compute_s == trace.compute_s
    assert prog.comm_s == trace.comm_s
    print(f"cost contract: program total {prog.total_s:.6e}s == "
          f"simulate_epoch {trace.total_s:.6e}s ✓")

    opt = adam(linear_warmup_cosine(3e-3, 20, args.steps))
    step, _ = build_train_step(prog, mesh, opt, kernel_mode=args.kernel)

    params = replicate(fcnn.init(jax.random.PRNGKey(0), sizes), mesh)
    opt_state = opt.init(params)
    x, y = fcnn_classification_dataset(4096, input_dim=sizes[0], seed=0)

    t0 = time.time()
    for i in range(args.steps):
        lo = (i * args.batch) % (len(x) - args.batch + 1)
        batch = {"x": jnp.asarray(x[lo:lo + args.batch]),
                 "y": jnp.asarray(y[lo:lo + args.batch])}
        params, opt_state, loss = step(params, opt_state, batch, i)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(loss):.4f}")
    dt = time.time() - t0
    print(f"\n{args.steps} program steps in {dt:.1f}s "
          f"({1e3 * dt / args.steps:.1f} ms/step)")
    final_acc = float(fcnn.accuracy(params, jnp.asarray(x), jnp.asarray(y),
                                    kernel_mode=args.kernel))
    print(f"final train accuracy: {final_acc:.3f}")
    assert final_acc > 0.8, "program-mode training failed to learn"


if __name__ == "__main__":
    main()
