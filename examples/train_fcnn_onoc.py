"""End-to-end driver: train the paper's FCNN (reduced NN1) on the synthetic
fashion-mnist-shaped dataset for a few hundred steps, with the per-layer
parallelism degrees chosen by the ONoC planner and realized as JAX
shardings.

  PYTHONPATH=src python examples/train_fcnn_onoc.py [--steps 300]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.core.planner import plan_fcnn
from repro.data import Batcher, fcnn_classification_dataset
from repro.launch.mesh import make_host_mesh
from repro.models import fcnn
from repro.optim import adam, linear_warmup_cosine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kernel", default=None,
                    choices=["ref", "pallas", "pallas_interpret"],
                    help="force the fcnn_layer dispatch mode (default: "
                         "fused Pallas fwd+bwd on TPU, jnp oracle elsewhere)")
    args = ap.parse_args()

    # reduced NN1 (784-1000-500-10 -> 784-256-128-10) so CPU runs fast
    sizes = [784, 256, 128, 10]
    workload = FCNNWorkload(sizes, batch_size=args.batch)
    onoc = ONoCConfig(m=1000, lambda_max=64)

    mesh = make_host_mesh()
    plan = plan_fcnn(workload, onoc, dict(mesh.shape), strategy="orrm")
    print("ONoC plan (per layer): "
          + ", ".join(f"L{p.period}: m*={p.onoc_cores} -> degree {p.degree}"
                      for p in plan.periods))

    key = jax.random.PRNGKey(0)
    params = fcnn.init(key, sizes)
    opt = adam(linear_warmup_cosine(3e-3, 20, args.steps))
    opt_state = opt.init(params)

    x, y = fcnn_classification_dataset(4096, input_dim=sizes[0], seed=0)
    batches = Batcher({"x": x, "y": y}, batch_size=args.batch, mesh=mesh)

    @jax.jit
    def step(params, opt_state, batch, i):
        loss, grads = jax.value_and_grad(
            lambda p, b: fcnn.loss_fn(p, b, kernel_mode=args.kernel)
        )(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, loss

    t0 = time.time()
    with mesh:
        for i in range(args.steps):
            batch = next(batches)
            params, opt_state, loss = step(params, opt_state, batch, i)
            if i % 50 == 0 or i == args.steps - 1:
                acc = fcnn.accuracy(params, jnp.asarray(x[:1024]),
                                    jnp.asarray(y[:1024]),
                                    kernel_mode=args.kernel)
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"acc {float(acc):.3f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({1e3 * dt / args.steps:.1f} ms/step)")
    final_acc = float(fcnn.accuracy(params, jnp.asarray(x), jnp.asarray(y),
                                    kernel_mode=args.kernel))
    print(f"final train accuracy: {final_acc:.3f}")
    assert final_acc > 0.8, "training failed to learn"


if __name__ == "__main__":
    main()
