"""End-to-end driver: train the paper's FCNN (reduced NN1) on the synthetic
fashion-mnist-shaped dataset for a few hundred steps, with the per-layer
parallelism degrees chosen by the ONoC planner and realized as JAX
shardings.

  PYTHONPATH=src python examples/train_fcnn_onoc.py [--steps 300]

With ``--program N`` the planner's schedule is *executed* instead of just
priced, through the one-call façade ``repro.exec.compile(...)``: the plan
is compiled to a static RUN/SEND/RECV/FREE period program with residency
annotations (exec/program.py, schema v2), statically validated and
cross-checked against core.simulator.simulate_epoch, and interpreted
under shard_map on an N-device CPU ring (exec/runtime.py).  The default
``--residency sharded`` keeps each device to ~1/d of the model (its
column chunks, dropped at the Eq.-11 mirror periods); ``--residency
replicated`` runs the full-model oracle:

  PYTHONPATH=src python examples/train_fcnn_onoc.py --program 8 --steps 100
"""

import argparse
import sys
import time

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--kernel", default=None,
                    choices=["ref", "pallas", "pallas_interpret"],
                    help="force the fcnn_layer dispatch mode (default: "
                         "fused Pallas fwd+bwd on TPU, jnp oracle elsewhere)")
    ap.add_argument("--program", type=int, default=0, metavar="N",
                    help="compile the plan to a period program and execute "
                         "it under shard_map on an N-device CPU ring")
    ap.add_argument("--strategy", default="orrm",
                    choices=["fm", "rrm", "orrm"],
                    help="core mapping strategy (program mode)")
    ap.add_argument("--residency", default="sharded",
                    choices=["sharded", "replicated"],
                    help="program-mode params layout: per-device column "
                         "chunks (~1/d resident bytes) or the full-model "
                         "replicated oracle")
    args = ap.parse_args()

    if args.program:
        # must run before any other jax backend touch (forces N CPU devices)
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(args.program)
    else:
        mesh = None

    import jax
    import jax.numpy as jnp

    from repro.core.onoc_model import FCNNWorkload, ONoCConfig
    from repro.core.planner import plan_fcnn
    from repro.data import Batcher, fcnn_classification_dataset
    from repro.launch.mesh import make_host_mesh
    from repro.models import fcnn
    from repro.optim import adam, linear_warmup_cosine

    # reduced NN1 (784-1000-500-10 -> 784-256-128-10) so CPU runs fast
    sizes = [784, 256, 128, 10]
    workload = FCNNWorkload(sizes, batch_size=args.batch)
    onoc = ONoCConfig(m=1000, lambda_max=64)

    if args.program:
        _run_program_mode(args, workload, onoc, mesh)
        return

    mesh = make_host_mesh()
    plan = plan_fcnn(workload, onoc, dict(mesh.shape), strategy="orrm")
    print("ONoC plan (per layer): "
          + ", ".join(f"L{p.period}: m*={p.onoc_cores} -> degree {p.degree}"
                      for p in plan.periods))

    key = jax.random.PRNGKey(0)
    params = fcnn.init(key, sizes)
    opt = adam(linear_warmup_cosine(3e-3, 20, args.steps))
    opt_state = opt.init(params)

    x, y = fcnn_classification_dataset(4096, input_dim=sizes[0], seed=0)
    batches = Batcher({"x": x, "y": y}, batch_size=args.batch, mesh=mesh)

    @jax.jit
    def step(params, opt_state, batch, i):
        loss, grads = jax.value_and_grad(
            lambda p, b: fcnn.loss_fn(p, b, kernel_mode=args.kernel)
        )(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, loss

    t0 = time.time()
    with mesh:
        for i in range(args.steps):
            batch = next(batches)
            params, opt_state, loss = step(params, opt_state, batch, i)
            if i % 50 == 0 or i == args.steps - 1:
                acc = fcnn.accuracy(params, jnp.asarray(x[:1024]),
                                    jnp.asarray(y[:1024]),
                                    kernel_mode=args.kernel)
                print(f"step {i:4d}  loss {float(loss):.4f}  "
                      f"acc {float(acc):.3f}")
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({1e3 * dt / args.steps:.1f} ms/step)")
    final_acc = float(fcnn.accuracy(params, jnp.asarray(x), jnp.asarray(y),
                                    kernel_mode=args.kernel))
    print(f"final train accuracy: {final_acc:.3f}")
    assert final_acc > 0.8, "training failed to learn"


def _run_program_mode(args, workload, onoc, mesh) -> None:
    """Compile + execute the plan via the ``repro.exec.compile`` façade:
    cross-check the program's cost annotations against the simulator, show
    the residency profile, and train through the Executable."""
    import jax
    import jax.numpy as jnp

    import repro.exec as rexec
    from repro.core.simulator import simulate_epoch
    from repro.data import fcnn_classification_dataset
    from repro.models import fcnn
    from repro.optim import adam, linear_warmup_cosine

    n = args.program
    sizes = list(workload.layer_sizes)
    exe = rexec.compile(workload, onoc, mesh, strategy=args.strategy,
                        residency=args.residency, kernel_mode=args.kernel)
    prog = exe.program
    print(f"compiled {args.strategy.upper()} program (schema v"
          f"{prog.version}, {args.residency} residency): "
          f"{len(prog.instructions)} instructions over {2 * prog.l} periods "
          f"on a {n}-device ring")
    for i in prog.instructions:
        extra = (f" layer={i.layer} {i.phase} m*={i.onoc_cores} "
                 f"degree={i.degree}" if i.opcode.value == "run" else "")
        if i.opcode.value == "free" and i.layer is not None:
            extra = f" layer={i.layer} param_bytes={i.param_bytes:.0f}"
        print(f"  P{i.period:>2} {i.opcode.value.upper():<4} "
              f"devices={list(i.devices)} cost={i.cost_s:.3e}s{extra}")

    trace = simulate_epoch(workload, onoc, mapping=exe.plan.mapping)
    assert prog.compute_s == trace.compute_s
    assert prog.comm_s == trace.comm_s
    print(f"cost contract: program total {prog.total_s:.6e}s == "
          f"simulate_epoch {trace.total_s:.6e}s ✓")

    from repro.exec.residency import replicated_model_bytes
    tr = exe.tracker
    full = replicated_model_bytes(prog)
    print(f"residency ({args.residency}): peak {max(tr.peak_bytes()):.0f} B"
          f"/device vs {full:.0f} B replicated "
          f"(ratio {tr.peak_ratio():.3f}); FREEs release at periods "
          f"{tr.release_periods()}")

    opt = adam(linear_warmup_cosine(3e-3, 20, args.steps))
    state = exe.init_state(jax.random.PRNGKey(0), opt)
    step = exe.train_step(opt)
    x, y = fcnn_classification_dataset(4096, input_dim=sizes[0], seed=0)

    t0 = time.time()
    for i in range(args.steps):
        lo = (i * args.batch) % (len(x) - args.batch + 1)
        batch = {"x": jnp.asarray(x[lo:lo + args.batch]),
                 "y": jnp.asarray(y[lo:lo + args.batch])}
        state, metrics = step(state, batch)
        if i % 50 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}")
    dt = time.time() - t0
    print(f"\n{args.steps} program steps in {dt:.1f}s "
          f"({1e3 * dt / args.steps:.1f} ms/step)")
    params = (exe.gather_params(state["params"])
              if args.residency == "sharded" else state["params"])
    final_acc = float(fcnn.accuracy(params, jnp.asarray(x), jnp.asarray(y),
                                    kernel_mode=args.kernel))
    print(f"final train accuracy: {final_acc:.3f}")
    assert final_acc > 0.8, "program-mode training failed to learn"


if __name__ == "__main__":
    main()
