"""Quickstart: the paper's pipeline in 60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. Define an FCNN workload (NN2 from the paper's Table 6).
2. Derive the optimal per-period core allocation (Lemma 1).
3. Place it on the ring with ORRM (Algorithm 1) and inspect the §4 analyses.
4. Simulate one training epoch on ONoC vs ENoC and compare time + energy.
"""

import sys
sys.path.insert(0, "src")

from repro.core import (
    ENoCBackend,
    FCNNWorkload,
    ONoCConfig,
    analyze_mapping,
    enoc_energy,
    map_cores,
    onoc_energy,
    optimal_cores,
    optimal_epoch_time,
    simulate_epoch,
)

# 1. workload + platform -----------------------------------------------------
workload = FCNNWorkload([784, 1500, 784, 1000, 500, 10], batch_size=32)
cfg = ONoCConfig(m=1000, lambda_max=64)

# 2. the paper's optimal allocation (Lemma 1) --------------------------------
stars = optimal_cores(workload, cfg, refine_plateau=True)
t_star, _, periods = optimal_epoch_time(workload, cfg, refine_plateau=True)
print(f"optimal cores per layer: {stars}")
print(f"predicted epoch time:    {t_star * 1e6:.1f} us")
for p in periods[: workload.l]:
    print(f"  period {p.period} (layer {p.layer}): m={p.m} "
          f"compute={p.compute_s * 1e6:.1f}us comm={p.comm_s * 1e6:.1f}us")

# 3. placement + Section-4 analyses ------------------------------------------
mapping = map_cores(workload, cfg, "orrm", stars)
report = analyze_mapping(workload, mapping)
print(f"\nORRM placement: hotspot={report.hotspot_consecutive_periods} "
      f"consecutive periods, {report.state_transitions} state transitions,")
print(f"  max path {report.max_path_length_hops} hops "
      f"({report.worst_insertion_loss_db:.1f} dB worst-case insertion loss),")
print(f"  max per-core SRAM {report.max_memory_bytes / 1e6:.1f} MB")

# 4. ONoC vs ENoC ------------------------------------------------------------
tr_onoc = simulate_epoch(workload, cfg, mapping=mapping)
tr_enoc = simulate_epoch(workload, cfg, mapping=mapping, backend=ENoCBackend())
e_onoc = onoc_energy(tr_onoc, mapping, report.state_transitions)
e_enoc = enoc_energy(tr_enoc, mapping, report.state_transitions)
print(f"\nONoC: {tr_onoc.total_s * 1e6:.1f} us, {e_onoc.total_j * 1e3:.2f} mJ")
print(f"ENoC: {tr_enoc.total_s * 1e6:.1f} us, {e_enoc.total_j * 1e3:.2f} mJ")
print(f"time reduction  {100 * (1 - tr_onoc.total_s / tr_enoc.total_s):.1f}% "
      f"(paper avg: 21.02% @ bs64)")
print(f"energy saving   {100 * (1 - e_onoc.total_j / e_enoc.total_j):.1f}% "
      f"(paper avg: 47.85% @ bs64)")
