"""Serve a small LM through the continuous-batching subsystem
(``repro.serve``): seeded open-loop traffic, per-slot admission prefill,
batched decode, SLO report.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-14b]
                                             [--scenario steady]

Runs the chosen traffic preset (steady | burst | drain |
device-loss-mid-decode) on the smoke-sized config so it completes on
CPU; on a TPU mesh the identical code path serves the full config.  The
device-loss preset demonstrates the Lemma-1 elastic replan mid-decode —
in-flight requests restart from their prompts and finish with identical
token streams.
"""

import argparse
import subprocess
import sys

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--scenario", default="steady")
    args = ap.parse_args()
    # the serving loop lives in the launcher; this example drives it the
    # way an operator would
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
           "--smoke", "--scenario", args.scenario,
           "--requests", "8", "--slots", "3", "--seed", "0"]
    print("$", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               **__import__("os").environ}))


if __name__ == "__main__":
    main()
