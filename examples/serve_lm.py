"""Serve a small LM with batched requests through the continuous-batching
slot manager (prefill + decode with KV cache).

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-14b]

Uses the smoke-sized config of the chosen architecture so it runs on CPU;
on a TPU mesh the identical code path serves the full config.
"""

import argparse
import subprocess
import sys

sys.path.insert(0, "src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b")
    args = ap.parse_args()
    # the serving loop lives in the launcher; this example drives it the
    # way an operator would
    cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
           "--smoke", "--requests", "8", "--slots", "4",
           "--prompt-len", "24", "--gen", "12"]
    print("$", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={"PYTHONPATH": "src",
                                               **__import__("os").environ}))


if __name__ == "__main__":
    main()
