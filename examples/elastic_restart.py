"""Fault-tolerance scenarios: crash-restart, elastic replanning, and the
full seeded device-loss -> replan -> checkpoint-resume loop.

  PYTHONPATH=src python examples/elastic_restart.py

Fault taxonomy (``repro.runtime.faults.FaultKind``):

  DEVICE_LOSS          a core leaves the ring permanently — fatal to the
                       current mesh, triggers replan + resume (below);
  TRANSIENT_RUN        one period's RUN fails but the device survives —
                       cleared by TrainingSupervisor's bounded retry with
                       exponential backoff;
  STRAGGLER            a period runs magnitude× slow — observed by
                       StragglerMonitor / the injector's timeout hook;
  WAVELENGTH_DEGRADE   part of the WDM comb is lost — more TDM slots per
                       transition in the pricing model;
  LINK_DEGRADE         link capacity loss — transition drain inflates by
                       1/(1-magnitude).

Injection API: build a deterministic ``FaultSchedule`` (hand-authored
events, ``FaultSchedule.sample`` for Bernoulli-per-step rates, or
``FaultSchedule.seeded_device_loss`` for one mid-run loss burst) and
either price it (``simulate_epoch(..., faults=EpochFaults.from_schedule)``
/ ``expected_epoch_time``) or execute it: ``DegradedModeRunner`` walks the
compiled period program's instruction list each step and lets the
``FaultInjector`` fire events at instruction boundaries.

Replan-resume flow (scenario 3 below, also the CI ``fault-smoke`` job):
on ``DeviceLossFault`` the runner asks ``ElasticPlanner.replan_program``
for the Lemma-1 allocation on the survivors, recompiles the period
program for the shrunken ring (statically re-validated by
``exec.validate``), rebuilds the mesh + executor, and re-enters
``TrainingSupervisor`` — which restores the latest complete checkpoint
(params, optimizer state *and* Batcher position, so no sample is skipped
or repeated) and resumes.  Because executor numerics are device-count
invariant, the resumed loss trajectory matches a from-scratch run on the
surviving mesh — asserted below.
"""

import dataclasses
import os
import shutil
import sys
import tempfile

sys.path.insert(0, "src")

# scenario 3 executes on an 8-device CPU ring: force host devices before
# the first jax import (no-op for already-multi-device backends).
_HOST_FLAG = "--xla_force_host_platform_device_count"
if _HOST_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{_HOST_FLAG}=8 " + os.environ.get("XLA_FLAGS", "")).strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.data import Batcher, fcnn_classification_dataset
from repro.models import fcnn
from repro.optim import adam
from repro.runtime import TrainingSupervisor
from repro.runtime.degraded import DegradedModeRunner
from repro.runtime.elastic import ElasticPlanner
from repro.runtime.faults import FaultSchedule


def crash_restart() -> None:
    """Scenario 1: transient crash mid-run; restart from checkpoint."""
    tmp = tempfile.mkdtemp(prefix="repro_elastic_")
    sizes = [64, 128, 64, 10]
    key = jax.random.PRNGKey(0)
    opt = adam(3e-3)

    params = fcnn.init(key, sizes)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    x, y = fcnn_classification_dataset(1024, input_dim=64, seed=1)
    batches = Batcher({"x": x, "y": y}, batch_size=32)

    fail_at = {"n": 0}

    @jax.jit
    def _step(state, batch):
        loss, grads = jax.value_and_grad(fcnn.loss_fn)(state["params"], batch)
        p, o = opt.update(grads, state["opt"], state["params"], state["step"])
        return {"params": p, "opt": o, "step": state["step"] + 1}, loss

    def step_fn(state, batch):
        fail_at["n"] += 1
        if fail_at["n"] == 60:                      # injected crash
            raise RuntimeError("simulated node failure")
        state, loss = _step(state, batch)
        return state, {"loss": float(loss)}

    sup = TrainingSupervisor(Checkpointer(tmp), checkpoint_every=20,
                             max_retries=0, backoff_s=0.0)
    state, history = sup.run(state, step_fn, batches, 100)
    print(f"completed {len(history)} steps with 1 injected failure; "
          f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")
    assert history[-1]["loss"] < history[0]["loss"]
    shutil.rmtree(tmp, ignore_errors=True)


def elastic_shrink() -> None:
    """Scenario 2: the paper's model as the re-planning oracle."""
    planner = ElasticPlanner(FCNNWorkload([64, 128, 64, 10], batch_size=32),
                             ONoCConfig(m=1000, lambda_max=64))
    for m in (1000, 500, 100):
        _, cores, mapping = planner.plan_for(m)
        print(f"cluster size {m:4d}: allocation {cores} "
              f"({mapping.strategy.value} placement, "
              f"{len(mapping.active_cores())} active)")


def device_loss_replan_resume() -> None:
    """Scenario 3 (the CI fault-smoke): seeded mid-run device loss on the
    8-device CPU ring -> Lemma-1 replan on survivors -> checkpoint-resume;
    the resumed trajectory must match a from-scratch run on the small
    mesh.  Both runners execute the *weight-sharded* residency path
    (ISSUE 8): params are sliced once at step start into per-device
    column chunks and each replan re-derives the survivor ring's chunk
    geometry — residency recovery is exercised, not just replanning."""
    sizes = [32, 16, 8, 10]
    n_dev, n_steps, batch = 8, 8, 8
    w = FCNNWorkload(sizes, batch_size=batch)
    cfg = ONoCConfig(m=n_dev, lambda_max=64)
    x, y = fcnn_classification_dataset(64, input_dim=sizes[0], seed=3)
    params0 = fcnn.init(jax.random.PRNGKey(0), sizes)
    opt = adam(1e-2)

    schedule = FaultSchedule.seeded_device_loss(
        0, n_steps=n_steps, n_devices=n_dev, n_periods=2 * w.l)
    survivors = n_dev - len(schedule.events)

    with tempfile.TemporaryDirectory() as tmp:
        runner = DegradedModeRunner(
            workload=w, base_cfg=cfg, schedule=schedule,
            checkpointer=Checkpointer(tmp), optimizer=opt, n_devices=n_dev,
            kernel_mode="ref", residency="sharded", checkpoint_every=2,
            backoff_s=0.0)
        state, _, report = runner.run(
            params0, opt.init(params0),
            Batcher({"x": x, "y": y}, batch_size=batch), n_steps)

    with tempfile.TemporaryDirectory() as tmp:
        scratch = DegradedModeRunner(
            workload=w, base_cfg=dataclasses.replace(cfg, m=survivors),
            schedule=FaultSchedule(), checkpointer=Checkpointer(tmp),
            optimizer=opt, n_devices=survivors, kernel_mode="ref",
            residency="sharded", checkpoint_every=2, backoff_s=0.0)
        scratch.run(params0, opt.init(params0),
                    Batcher({"x": x, "y": y}, batch_size=batch), n_steps)

    rp = report.replans[0]
    print(f"device loss at step {rp['step']} period {rp['period']}: "
          f"lost {rp['lost']}, replanned {rp['from_devices']} -> "
          f"{rp['to_devices']} devices, resumed from checkpoint "
          f"{rp['resume_checkpoint']}")
    assert len(report.replans) == 1 and int(state["step"]) == n_steps
    for s in range(n_steps):
        np.testing.assert_allclose(runner.losses[s], scratch.losses[s],
                                   rtol=1e-4, atol=1e-6)
    print(f"resumed trajectory matches from-scratch run on {survivors} "
          f"devices ({n_steps} steps)")


def main() -> None:
    crash_restart()
    elastic_shrink()
    device_loss_replan_resume()


if __name__ == "__main__":
    main()
