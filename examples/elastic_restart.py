"""Fault-tolerance scenario: train, crash, restart from checkpoint, then
shrink the cluster and let the ONoC planner re-derive the allocation.

  PYTHONPATH=src python examples/elastic_restart.py
"""

import shutil
import sys
import tempfile

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.data import Batcher, fcnn_classification_dataset
from repro.models import fcnn
from repro.optim import adam
from repro.runtime import TrainingSupervisor
from repro.runtime.elastic import ElasticPlanner


def main() -> None:
    tmp = tempfile.mkdtemp(prefix="repro_elastic_")
    sizes = [64, 128, 64, 10]
    key = jax.random.PRNGKey(0)
    opt = adam(3e-3)

    params = fcnn.init(key, sizes)
    state = {"params": params, "opt": opt.init(params),
             "step": jnp.zeros((), jnp.int32)}

    x, y = fcnn_classification_dataset(1024, input_dim=64, seed=1)
    batches = Batcher({"x": x, "y": y}, batch_size=32)

    fail_at = {"n": 0}

    @jax.jit
    def _step(state, batch):
        loss, grads = jax.value_and_grad(fcnn.loss_fn)(state["params"], batch)
        p, o = opt.update(grads, state["opt"], state["params"], state["step"])
        return {"params": p, "opt": o, "step": state["step"] + 1}, loss

    def step_fn(state, batch):
        fail_at["n"] += 1
        if fail_at["n"] == 60:                      # injected crash
            raise RuntimeError("simulated node failure")
        state, loss = _step(state, batch)
        return state, {"loss": float(loss)}

    sup = TrainingSupervisor(Checkpointer(tmp), checkpoint_every=20,
                             max_retries=0, backoff_s=0.0)
    state, history = sup.run(state, step_fn, batches, 100)
    print(f"completed {len(history)} steps with 1 injected failure; "
          f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")
    assert history[-1]["loss"] < history[0]["loss"]

    # elastic shrink: the paper's model is the re-planning oracle
    planner = ElasticPlanner(FCNNWorkload(sizes, batch_size=32),
                             ONoCConfig(m=1000, lambda_max=64))
    for m in (1000, 500, 100):
        _, cores, mapping = planner.plan_for(m)
        print(f"cluster size {m:4d}: allocation {cores} "
              f"({mapping.strategy.value} placement, "
              f"{len(mapping.active_cores())} active)")
    shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    main()
