"""Static verification of compiled period programs.

A ``PeriodProgram`` is plain data that gets shipped to workers and
re-generated on every replan — a silently corrupted schedule (a RECV whose
SEND was dropped, a window pointing off the mesh, a FREE that releases a
chunk the next period still needs) would execute as wrong numerics or a
deadlocked collective, not as an error.  ``validate_program`` turns every
such corruption into a hard, precisely-worded ``ProgramValidationError``.

It runs in two places:

  * compile time — ``exec.program.compile_program`` validates every
    program it emits (including the cost contract against the simulator),
  * replan time — the degraded-mode runner re-validates after every
    membership change before the new program is allowed to execute
    (runtime/degraded.py).

Checks, in order:

  structure     exactly one RUN per period 1..2l, periods non-decreasing,
                RUN geometry consistent (chunk_width · degree = n_layer,
                window length = degree, BP windows mirror FP via Eq. 11);
  mesh          every device id of every instruction lies in
                [0, n_devices);
  degrees       every RUN degree divides both the device count (uniform
                all-gather chunk layout) and its layer width (the paper's
                even-mapping constraint, Eq. 4 exact);
  SEND/RECV     transitions exactly at {1..2l-1} \\ {l}; every RECV has a
                matching SEND and vice versa; senders are the current RUN
                window and receivers the next RUN window;
  FREE          (window FREEs, ``layer`` is None) only devices held at the
                period are freed, never a device the next period's window
                still needs (free-before-last-use), each window exit freed
                exactly once, and the final window freed wholesale at
                period 2l;
  residency     (schema v2) every RUN carries positive ``param_bytes``
                agreeing between a layer's FP run and its BP mirror; each
                layer's chunks are released by exactly one param FREE
                (``layer`` set), at exactly the BP mirror period 2l-i+1
                (Eq. 11 — the chunk's last use), over exactly the layer's
                window, for exactly the RUN's bytes; no RUN executes on
                non-resident (already freed) chunks; the byte ledger
                drains to exactly zero on every device;
  costs         (with workload + cfg) RUN costs equal the paper-level
                ``compute_time`` and SEND costs the backend transition
                time under the simulator's conventions — the program's
                compute_s/comm_s must equal ``simulate_epoch`` exactly.
"""

from __future__ import annotations

import math

from repro.core.allocation import map_cores
from repro.core.onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    compute_time,
    period_layer,
)
from repro.core.simulator import ONoCBackend, ENoCBackend

__all__ = ["ProgramValidationError", "validate_program"]
# ProgramAnalysisError (exec.analysis.errors) subclasses
# ProgramValidationError: importing either module gives one taxonomy.

_REL_TOL = 1e-9


class ProgramValidationError(ValueError):
    """A compiled program violates the schedule invariants."""


def _fail(msg: str) -> None:
    raise ProgramValidationError(msg)


def validate_program(
    program,
    workload: FCNNWorkload | None = None,
    cfg: ONoCConfig | None = None,
    backend=None,
    analyze: str | None = None,
) -> None:
    """Raise ``ProgramValidationError`` on the first violated invariant.

    Structural checks always run.  The cost contract is checked only when
    ``workload`` and ``cfg`` are provided (the compile-time path); pass the
    ``backend`` the program was compiled against to price SENDs with a
    non-default configuration.

    ``analyze`` optionally delegates to the per-device static analyzer
    (``exec.analysis.analyze_program``) after these SPMD-level checks:
    ``"fast"`` adds the happens-before/endpoint/memory checks, ``"full"``
    also the shape abstract interpreter.  Analyzer rejections raise
    ``ProgramAnalysisError``, a subclass of this module's
    ``ProgramValidationError`` — one error taxonomy for both layers.
    """
    if analyze is not None and analyze != "off":
        # the analyzer runs this validator as its own pre-pass, so the
        # delegation replaces (not duplicates) the checks below
        from repro.exec.analysis import analyze_program
        analyze_program(program, workload, cfg, backend=backend,
                        level=analyze)
        return

    from repro.exec.program import Opcode

    l = program.l
    n_dev = program.n_devices
    instrs = list(program.instructions)

    # ---------------------------------------------------------- structure
    runs = {i.period: i for i in instrs if i.opcode is Opcode.RUN}
    if sorted(runs) != list(range(1, 2 * l + 1)):
        missing = sorted(set(range(1, 2 * l + 1)) - set(runs))
        _fail(f"program must have one RUN per period 1..{2 * l}; "
              f"missing periods {missing}" if missing else
              f"program has RUNs at unexpected periods {sorted(runs)}")
    n_runs = sum(1 for i in instrs if i.opcode is Opcode.RUN)
    if n_runs != 2 * l:
        _fail(f"expected {2 * l} RUN instructions, found {n_runs}")
    periods = [i.period for i in instrs]
    if periods != sorted(periods):
        _fail(f"instructions out of period order: {periods}")

    for p, run in runs.items():
        layer = run.layer
        if workload is not None and layer != period_layer(workload, p):
            _fail(f"RUN period {p}: layer {layer} != paper period-layer "
                  f"{period_layer(workload, p)}")
        n_layer = program.layer_sizes[layer]
        d = run.degree
        if d != len(run.devices):
            _fail(f"RUN period {p}: degree {d} != window size "
                  f"{len(run.devices)}")
        if len(set(run.devices)) != len(run.devices):
            _fail(f"RUN period {p}: window has duplicate devices "
                  f"{list(run.devices)}")
        if d < 1 or n_dev % d != 0:
            _fail(f"RUN period {p}: degree {d} does not divide the device "
                  f"count {n_dev} (non-uniform all-gather chunk layout)")
        if n_layer % d != 0:
            _fail(f"RUN period {p}: degree {d} does not divide layer width "
                  f"{n_layer} (even-mapping constraint, Eq. 4)")
        if run.chunk_width != n_layer // d:
            _fail(f"RUN period {p}: chunk_width {run.chunk_width} != "
                  f"{n_layer} / {d}")
    # Eq. 11: BP windows mirror FP windows
    for i in range(1, l + 1):
        fp, bp = runs[i], runs[2 * l - i + 1]
        if fp.devices != bp.devices:
            _fail(f"BP period {2 * l - i + 1} window {list(bp.devices)} != "
                  f"FP period {i} window {list(fp.devices)} "
                  f"(data-locality constraint, Eq. 11)")

    # --------------------------------------------------------------- mesh
    for ins in instrs:
        bad = [d for d in ins.devices if not 0 <= d < n_dev]
        if bad:
            _fail(f"{ins.opcode.value.upper()} period {ins.period}: devices "
                  f"{bad} outside the {n_dev}-device mesh [0, {n_dev})")

    # ---------------------------------------------------------- SEND/RECV
    sends = {i.period: i for i in instrs if i.opcode is Opcode.SEND}
    recvs = {i.period: i for i in instrs if i.opcode is Opcode.RECV}
    want = set(range(1, 2 * l)) - {l}
    for p in sorted(recvs):
        if p not in sends:
            _fail(f"dangling RECV at period {p}: no matching SEND "
                  f"(receivers {list(recvs[p].devices)} would wait forever)")
    for p in sorted(sends):
        if p not in recvs:
            _fail(f"dangling SEND at period {p}: no matching RECV")
    if set(sends) != want:
        _fail(f"transition periods {sorted(sends)} != "
              f"{sorted(want)} (Eq. 6: 2l-2 transitions, none at the "
              f"period-l turnaround)")
    for p, s in sends.items():
        if tuple(s.devices) != tuple(runs[p].devices):
            _fail(f"SEND period {p}: senders {list(s.devices)} != period-{p} "
                  f"RUN window {list(runs[p].devices)}")
        if tuple(recvs[p].devices) != tuple(runs[p + 1].devices):
            _fail(f"RECV period {p}: receivers {list(recvs[p].devices)} != "
                  f"period-{p + 1} RUN window {list(runs[p + 1].devices)}")

    # ------------------------------------------------- FREE (window kind)
    frees: dict[int, list] = {}
    for ins in instrs:
        if ins.opcode is Opcode.FREE and ins.layer is None:
            frees.setdefault(ins.period, []).append(ins)
    for p, fs in frees.items():
        released = [d for f in fs for d in f.devices]
        if len(set(released)) != len(released):
            _fail(f"FREE period {p}: device(s) "
                  f"{sorted(set(d for d in released if released.count(d) > 1))}"
                  f" double-freed")
        held = set(runs[p].devices)
        ghost = sorted(set(released) - held)
        if ghost:
            _fail(f"FREE period {p}: devices {ghost} not in the period's "
                  f"window {sorted(held)} — cannot free what is not held")
        if p < 2 * l:
            needed = set(runs[p + 1].devices)
            early = sorted(set(released) & needed)
            if early:
                _fail(f"FREE period {p}: devices {early} are freed before "
                      f"last use — period {p + 1}'s window still needs "
                      f"their chunks")
    for p in range(1, 2 * l):
        leaving = set(runs[p].devices) - set(runs[p + 1].devices)
        released = {d for f in frees.get(p, []) for d in f.devices}
        leaked = sorted(leaving - released)
        if leaked:
            _fail(f"period {p}: devices {leaked} leave the active window "
                  f"but are never freed (residency leak)")
    final_released = {d for f in frees.get(2 * l, []) for d in f.devices}
    if final_released != set(runs[2 * l].devices):
        _fail(f"period {2 * l}: final FREE releases "
              f"{sorted(final_released)} != final window "
              f"{sorted(runs[2 * l].devices)}")

    # ---------------------------------------------- residency (schema v2)
    if program.version >= 2:
        param_frees = [i for i in instrs if i.opcode is Opcode.FREE
                       and i.layer is not None]
        for layer in range(1, l + 1):
            fp = runs[layer]
            bp = runs[2 * l - layer + 1]
            if fp.param_bytes <= 0.0:
                _fail(f"RUN period {layer}: param_bytes "
                      f"{fp.param_bytes!r} must be positive (schema v2 "
                      f"residency annotation)")
            if bp.param_bytes != fp.param_bytes:
                _fail(f"RUN period {2 * l - layer + 1}: BP param_bytes "
                      f"{bp.param_bytes!r} != FP mirror's "
                      f"{fp.param_bytes!r} (layer {layer} chunks are "
                      f"reused, not re-acquired)")
            lf = [f for f in param_frees if f.layer == layer]
            if len(lf) != 1:
                _fail(f"layer {layer}: expected exactly one param FREE, "
                      f"found {len(lf)} (chunk residency ledger)")
            f = lf[0]
            mirror = 2 * l - layer + 1
            if f.period != mirror:
                _fail(f"param FREE for layer {layer} at period {f.period} "
                      f"!= BP mirror period {mirror} (Eq. 11: the chunk's "
                      f"last use)")
            if set(f.devices) != set(fp.devices):
                _fail(f"param FREE for layer {layer}: devices "
                      f"{sorted(f.devices)} != layer window "
                      f"{sorted(fp.devices)}")
            if f.param_bytes != fp.param_bytes:
                _fail(f"param FREE for layer {layer}: releases "
                      f"{f.param_bytes!r} bytes != resident chunk bytes "
                      f"{fp.param_bytes!r} (ledger would not drain)")
        bad_layers = sorted({f.layer for f in param_frees}
                            - set(range(1, l + 1)))
        if bad_layers:
            _fail(f"param FREE for unknown layer(s) {bad_layers}")
        # ordered walk: a RUN after its layer's param FREE touches
        # non-resident chunks
        freed: set[int] = set()
        for ins in instrs:
            if ins.opcode is Opcode.RUN and ins.layer in freed:
                _fail(f"RUN period {ins.period}: layer {ins.layer} chunks "
                      f"are non-resident (freed by an earlier param FREE) "
                      f"— RUN operands must be resident")
            if ins.opcode is Opcode.FREE and ins.layer is not None:
                freed.add(ins.layer)
        # per-device ledger: acquired bytes must drain to exactly zero
        acquired = [0.0] * n_dev
        for layer in range(1, l + 1):
            for d in runs[layer].devices:
                acquired[d] += runs[layer].param_bytes
        for f in param_frees:
            for d in f.devices:
                acquired[d] -= f.param_bytes
        leaky = [d for d in range(n_dev) if acquired[d] != 0.0]
        if leaky:
            _fail(f"residency ledger does not drain to zero on device(s) "
                  f"{leaky}: residual bytes "
                  f"{[acquired[d] for d in leaky]}")
        if workload is not None and cfg is not None:
            for layer in range(1, l + 1):
                run = runs[layer]
                want = float((workload.n(layer - 1) + 1) * run.chunk_width
                             * cfg.bytes_per_value)
                if run.param_bytes != want:
                    _fail(f"RUN period {layer}: param_bytes "
                          f"{run.param_bytes!r} != chunk geometry "
                          f"(n_{layer - 1}+1) x chunk_width x "
                          f"bytes_per_value = {want!r}")

    # -------------------------------------------------------------- costs
    if workload is None or cfg is None:
        return
    if tuple(int(n) for n in workload.layer_sizes) != program.layer_sizes:
        _fail(f"workload layer sizes {list(workload.layer_sizes)} != "
              f"program layer sizes {list(program.layer_sizes)}")
    if backend is None:
        backend = ONoCBackend() if program.backend == "onoc" else ENoCBackend()
    if backend.name != program.backend:
        _fail(f"backend {backend.name!r} != program backend "
              f"{program.backend!r}")
    paper_mapping = map_cores(workload, cfg, program.strategy,
                              list(program.onoc_cores))
    for p, run in runs.items():
        m_star = len(paper_mapping.window(p))
        if run.onoc_cores != m_star:
            _fail(f"RUN period {p}: onoc_cores {run.onoc_cores} != paper "
                  f"window size {m_star}")
        want_cost = compute_time(workload, cfg, p, m_star)
        if not math.isclose(run.cost_s, want_cost, rel_tol=_REL_TOL,
                            abs_tol=0.0):
            _fail(f"RUN period {p}: cost {run.cost_s!r} != paper-level "
                  f"compute_time {want_cost!r} (simulator contract)")
    for p, s in sends.items():
        tr = backend.transition_time(workload, cfg, p, paper_mapping)
        want_cost = tr.comm_s
        if backend.name == "onoc" and p == 1:
            want_cost = 0.0  # Eq. (6): g(m_1) = 0
        if not math.isclose(s.cost_s, want_cost, rel_tol=_REL_TOL,
                            abs_tol=0.0):
            _fail(f"SEND period {p}: cost {s.cost_s!r} != backend "
                  f"transition_time {want_cost!r} (simulator contract)")
