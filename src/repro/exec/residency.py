"""Per-device parameter-residency accounting for compiled programs.

Turns a schema-v2 ``PeriodProgram``'s residency annotations (``param_bytes``
on RUN, param FREEs at each layer's BP mirror period) into a per-device
live-bytes timeline, so tests and benchmarks can assert the tentpole claim
of the weight-sharded executor: per-device peak live parameter bytes scale
as ~1/d versus the replicated oracle, and FREE instructions *release*
residency at exactly the scheduled periods.

Two modes mirror the two executor paths (see exec/runtime.py):

  * ``"sharded"``  — at step start each device acquires the column chunks
    of every layer whose FP window contains it (``param_bytes`` per layer);
    a param FREE at the layer's BP mirror period 2l-i+1 (Eq. 11, the
    chunk's last use) subtracts those bytes.  The ledger must drain to
    exactly zero by period 2l.
  * ``"replicated"`` — the PR-6 oracle: every device holds the full model
    for the whole epoch; FREE is a cost annotation, nothing is released.

The tracker is pure accounting over program annotations — it does not
execute anything.  ``exec.validate`` separately checks the annotations
themselves are consistent (bytes match geometry, FREEs sit at the mirror
periods, no RUN touches freed chunks).
"""

from __future__ import annotations

import dataclasses

from repro.exec.program import PeriodProgram

__all__ = ["ResidencySnapshot", "ResidencyTracker", "replicated_model_bytes"]


def replicated_model_bytes(program: PeriodProgram) -> float:
    """Full-model parameter bytes one device holds under replication.

    Recovered from the program's own annotations: a layer's full weight
    matrix is ``degree`` column chunks of ``param_bytes`` each.
    """
    return float(sum(r.param_bytes * r.degree for r in program.runs("fp")))


@dataclasses.dataclass(frozen=True)
class ResidencySnapshot:
    """Live parameter bytes per device *after* ``period``'s instructions.

    ``period == 0`` is the acquisition snapshot: chunks placed at step
    start, before any instruction runs.
    """

    period: int
    live_bytes: tuple[float, ...]

    @property
    def peak(self) -> float:
        return max(self.live_bytes)


class ResidencyTracker:
    """Walk a program's residency annotations into per-device timelines."""

    def __init__(self, program: PeriodProgram, mode: str = "sharded"):
        if mode not in ("sharded", "replicated"):
            raise ValueError(f"mode must be 'sharded' or 'replicated', "
                             f"got {mode!r}")
        if mode == "sharded" and program.version < 2:
            raise ValueError(
                f"program schema v{program.version} has no residency "
                f"annotations; recompile with compile_program for sharded "
                f"residency tracking")
        self.program = program
        self.mode = mode
        self.n_devices = program.n_devices
        self._snapshots = self._walk()

    # ------------------------------------------------------------- walking

    def _acquire(self) -> list[float]:
        live = [0.0] * self.n_devices
        if self.mode == "replicated":
            full = replicated_model_bytes(self.program)
            return [full] * self.n_devices
        for run in self.program.runs("fp"):
            for dev in run.devices:
                live[dev] += run.param_bytes
        return live

    def _walk(self) -> list[ResidencySnapshot]:
        live = self._acquire()
        snaps = [ResidencySnapshot(0, tuple(live))]
        n_periods = 2 * self.program.l
        by_period: dict[int, list] = {p: [] for p in range(1, n_periods + 1)}
        for f in self.program.frees("param"):
            by_period[f.period].append(f)
        for p in range(1, n_periods + 1):
            if self.mode == "sharded":
                for f in by_period[p]:
                    for dev in f.devices:
                        live[dev] -= f.param_bytes
            snaps.append(ResidencySnapshot(p, tuple(live)))
        return snaps

    # ------------------------------------------------------------- queries

    def timeline(self) -> list[ResidencySnapshot]:
        """Snapshots at period 0 (acquisition) and after each period."""
        return list(self._snapshots)

    def live_at(self, period: int) -> tuple[float, ...]:
        """Per-device bytes live *while* ``period`` executes — i.e. after
        the frees of all earlier periods (period p sees snapshot p-1)."""
        if not 1 <= period <= 2 * self.program.l:
            raise ValueError(f"period out of range: {period}")
        return self._snapshots[period - 1].live_bytes

    def peak_bytes(self) -> tuple[float, ...]:
        """Per-device peak live parameter bytes over the epoch."""
        return tuple(
            max(s.live_bytes[d] for s in self._snapshots)
            for d in range(self.n_devices)
        )

    def final_bytes(self) -> tuple[float, ...]:
        """Per-device bytes after period 2l — zero iff the ledger drains."""
        return self._snapshots[-1].live_bytes

    def release_periods(self) -> list[int]:
        """Periods at which any device's live bytes strictly decreased."""
        out = []
        for prev, cur in zip(self._snapshots, self._snapshots[1:]):
            if any(c < p for p, c in zip(prev.live_bytes, cur.live_bytes)):
                out.append(cur.period)
        return out

    def peak_ratio(self) -> float:
        """max-device sharded peak / replicated full-model bytes (<= 1;
        equals 1/d on a uniform-degree ring)."""
        full = replicated_model_bytes(self.program)
        if self.mode == "replicated":
            return 1.0
        return max(self.peak_bytes()) / full if full else 0.0
