"""One-call plan -> compile -> validate -> execute façade (ISSUE 8).

The PR-6/7 surface scattered the pipeline over four entry points
(``compile_fcnn_program`` -> ``validate_program`` -> ``ProgramExecutor``
-> ``build_fcnn_program_step`` / ``build_train_step``), each with its own
params-layout assumptions.  The weight-sharded residency path changes that
layout contract end to end, so this module collapses the chain into:

    exe = repro.exec.compile(workload, cfg, mesh, strategy="orrm",
                             residency="sharded")
    state = exe.init_state(key, optimizer)
    step = exe.train_step(optimizer)
    state, metrics = step(state, batch)

``residency`` selects the executor path (see exec/runtime.py):
``"sharded"`` (default) keeps each device's resident parameters to its
column chunks — state lives in the stacked layout produced by
``Executable.shard_params`` and FREE semantics are real; ``"replicated"``
is the PR-6 oracle (full model on every device), retained for
equivalence testing and as the layout of the generic model zoo step.
The old entry points remain importable as thin deprecation shims.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core.allocation import MappingStrategy
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.core.planner import FCNNPlan, plan_fcnn, ring_mesh_axes
from repro.exec.program import PeriodProgram, compile_program
from repro.exec.runtime import ProgramExecutor
from repro.optim.optimizers import Optimizer, clip_by_global_norm
from repro.parallel.sharding import replicate, shard_stacked

Params = dict[str, Any]

__all__ = ["Executable", "compile"]


@dataclasses.dataclass
class Executable:
    """A compiled, validated, mesh-bound period program ready to train.

    Produced by ``repro.exec.compile`` (or ``from_program`` when the
    ``PeriodProgram`` already exists, e.g. deserialized or replanned).
    The executor's residency mode fixes the params-layout contract for
    every method: ``init_state``/``train_step``/``loss_fn`` speak the
    stacked chunk layout in sharded mode and the full layout in
    replicated mode; ``shard_params``/``gather_params`` convert.
    """

    program: PeriodProgram
    mesh: Mesh
    executor: ProgramExecutor
    residency: str
    workload: FCNNWorkload | None = None
    cfg: ONoCConfig | None = None
    plan: FCNNPlan | None = None
    backend: Any = None

    @classmethod
    def from_program(cls, program: PeriodProgram, mesh: Mesh,
                     residency: str = "sharded",
                     kernel_mode: str | None = None,
                     workload: FCNNWorkload | None = None,
                     cfg: ONoCConfig | None = None,
                     plan: FCNNPlan | None = None,
                     backend: Any = None,
                     analyze: str = "off") -> "Executable":
        """Bind an existing program to ``mesh``.  ``analyze`` defaults to
        ``"off"`` because ``repro.exec.compile`` and the degraded-mode
        replan path analyze before binding; pass ``"fast"``/``"full"``
        for programs from untrusted sources (deserialized files)."""
        if analyze != "off":
            from repro.exec.analysis import analyze_program
            analyze_program(program, workload, cfg, backend=backend,
                            level=analyze)
        ex = ProgramExecutor(program, mesh, kernel_mode=kernel_mode,
                             residency=residency)
        return cls(program=program, mesh=mesh, executor=ex,
                   residency=residency, workload=workload, cfg=cfg,
                   plan=plan, backend=backend)

    # -------------------------------------------------------------- layout

    @property
    def tracker(self):
        """ResidencyTracker of the executor's layout (exec.residency)."""
        return self.executor.tracker

    @property
    def kernel_mode(self) -> str:
        return self.executor.kernel_mode

    def shard_params(self, params: Params) -> Params:
        return self.executor.shard_params(params)

    def gather_params(self, sparams: Params) -> Params:
        return self.executor.gather_params(sparams)

    def _place(self, tree: Any) -> Any:
        """Put a state pytree on the mesh in the residency layout: stacked
        leaves split over the ring axis in sharded mode, everything
        replicated otherwise (scalars always replicated)."""
        if self.residency != "sharded":
            return replicate(tree, self.mesh)
        return shard_stacked(tree, self.mesh, axis=self.executor.axis)

    # ----------------------------------------------------------- training

    def loss_fn(self, params: Params, batch: Params) -> jax.Array:
        """Program loss in the executable's residency layout (traceable;
        compose with jit/grad as usual)."""
        return self.executor.loss_fn(params, batch)

    def init_state(self, key, optimizer: Optimizer) -> Params:
        """Fresh ``{"params", "opt", "step"}`` state in the residency
        layout, placed on the mesh.  Optimizer moments mirror the params
        pytree, so in sharded mode they are chunked too — off-window zero
        chunks have zero grads and stay exactly zero through training."""
        from repro.models import fcnn

        params = fcnn.init(key, self.program.layer_sizes)
        if self.residency == "sharded":
            params = self.shard_params(params)
        state = {"params": params, "opt": optimizer.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        return self._place(state)

    def train_step(self, optimizer: Optimizer,
                   grad_clip: float | None = None,
                   donate: bool = True) -> Callable:
        """A jitted ``step(state, batch) -> (state, {"loss", "grad_norm"})``
        over the executable's loss.  ``grad_clip`` adds global-norm
        clipping (note: the global norm reduces over chunked leaves in
        sharded mode, so clipped trajectories agree with the replicated
        oracle only to fp tolerance; unclipped elementwise optimizers
        agree bit-for-bit)."""
        ex = self.executor

        def step(state, batch):
            loss, grads = jax.value_and_grad(ex.loss_fn)(state["params"],
                                                         batch)
            if grad_clip is not None:
                grads, gnorm = clip_by_global_norm(grads, grad_clip)
            else:
                gnorm = jnp.sqrt(sum(
                    jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads)))
            params, opt = optimizer.update(grads, state["opt"],
                                           state["params"], state["step"])
            new_state = {"params": params, "opt": opt,
                         "step": state["step"] + 1}
            return new_state, {"loss": loss, "grad_norm": gnorm}

        return jax.jit(step, donate_argnums=(0,) if donate else ())

    # ----------------------------------------------------------- recovery

    def degrade(self, mode: str = "ref") -> str:
        """Swap the kernel dispatch (exec/runtime ``degrade``) and return
        the previous mode.  Jitted steps built before the call hold the
        old dispatch — rebuild them via ``train_step``."""
        return self.executor.degrade(mode)


def compile(  # noqa: A001 — deliberate façade name, repro.exec.compile
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    mesh: Mesh,
    strategy: MappingStrategy | str = MappingStrategy.ORRM,
    residency: str = "sharded",
    backend: Any = None,
    kernel_mode: str | None = None,
    analyze: str = "full",
) -> Executable:
    """Plan (Lemma 1 on the divisor-complete ring), compile + statically
    validate the period program, and bind it to ``mesh`` as an
    ``Executable`` in the requested residency mode — the single entry
    point replacing the compile_fcnn_program / validate_program /
    ProgramExecutor / build_*_step chain.

    ``analyze`` selects the static-analysis level (``exec.analysis``)
    run on the compiled program before it is bound: ``"full"`` (default)
    adds the per-device happens-before/memory checks and the shape
    abstract interpreter on top of the validator; ``"fast"`` skips the
    shape interpreter and the cost contract; ``"off"`` leaves only the
    validator built into ``compile_program``.
    """
    n = mesh.devices.size
    plan = plan_fcnn(workload, cfg, ring_mesh_axes(n), strategy=strategy)
    program = compile_program(plan, workload, cfg, n, backend=backend)
    if analyze != "off":
        from repro.exec.analysis import analyze_program
        analyze_program(program, workload, cfg, backend=backend,
                        level=analyze)
    return Executable.from_program(
        program, mesh, residency=residency, kernel_mode=kernel_mode,
        workload=workload, cfg=cfg, plan=plan, backend=backend)
