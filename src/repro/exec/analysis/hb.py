"""Happens-before analysis over per-device instruction streams.

Three checkers, all consuming ``expand.expand_program`` output (plus the
SPMD program for endpoint annotations):

  * ``check_endpoints``   — every RECV's chunk-ordered ``sources`` must
    name exactly the senders of the matching SEND, in chunk order: the
    executor materializes chunk j of a period's activation from window
    device j, so a permuted source list silently reads another device's
    chunk (wrong numerics, not an error, at run time).
  * ``check_happens_before`` — builds the happens-before digraph (program
    order within each stream; a cross edge SEND(s,p) -> RECV(r,p) for
    every sender s and receiver r, since the gather blocks on *all*
    contributions, a device's own included) and rejects cycles: a cycle
    is a communication deadlock — every device on it waits for an event
    scheduled after its own wait.
  * ``check_memory``      — abstract per-device memory state at chunk
    granularity: the activation chunk a device holds (defined by RUN,
    redefined by RECV, killed by window FREE) and the liveness of each
    layer's param chunk (resident from step start, killed by its param
    FREE).  Flags use-before-def, use-after-FREE and double-FREE — the
    per-device orderings the SPMD validator's set/ledger checks cannot
    see (they are order-insensitive within a period).

All rejections raise ``ProgramAnalysisError`` naming the offending
device, period and (where applicable) chunk or cycle.
"""

from __future__ import annotations

from repro.exec.analysis.errors import ProgramAnalysisError
from repro.exec.analysis.expand import DeviceOp
from repro.exec.program import Opcode, PeriodProgram

__all__ = ["check_endpoints", "check_happens_before", "check_memory"]


def _fail(msg: str) -> None:
    raise ProgramAnalysisError(msg)


# --------------------------------------------------------------- endpoints

def check_endpoints(program: PeriodProgram) -> None:
    """RECV sources must match the senders of the same-period SEND, in
    chunk order (chunk j is computed and sent by sender window[j])."""
    sends = {i.period: i for i in program.instructions
             if i.opcode is Opcode.SEND}
    for ins in program.instructions:
        if ins.opcode is not Opcode.RECV:
            continue
        p = ins.period
        send = sends.get(p)
        if send is None:
            _fail(f"RECV period {p} on devices {list(ins.devices)}: no "
                  f"matching SEND — the receivers would wait forever "
                  f"(unmatched endpoint)")
        senders = tuple(send.devices)
        sources = tuple(ins.sources) or senders
        if len(sources) != len(senders):
            _fail(f"RECV period {p}: {len(sources)} sources "
                  f"{list(sources)} != {len(senders)} senders "
                  f"{list(senders)} of the period-{p} SEND (unmatched "
                  f"endpoint: chunk count disagrees)")
        if set(sources) != set(senders):
            _fail(f"RECV period {p}: sources {list(sources)} are not the "
                  f"senders {list(senders)} of the period-{p} SEND "
                  f"(unmatched endpoint)")
        for j, src in enumerate(sources):
            if src != senders[j]:
                _fail(f"RECV period {p} on devices {list(ins.devices)}: "
                      f"chunk {j} is declared to come from device {src}, "
                      f"but chunk {j} of the period-{p} activation is "
                      f"computed and sent by device {senders[j]} (swapped "
                      f"RECV source — the gather would read the wrong "
                      f"device's chunk)")


# --------------------------------------------------- happens-before graph

def check_happens_before(streams: dict[int, tuple[DeviceOp, ...]]) -> int:
    """Build the happens-before digraph and reject cycles (deadlocks).

    Nodes are (device, position-in-stream); edges are program order plus
    SEND -> RECV per transition period (a RECV waits on *every* sender's
    SEND — the gather needs all chunks, the receiver's own included).
    Returns the edge count (for analysis reports/benchmarks).
    """
    # node id = (device, pos); adjacency as index lists for the DFS
    nodes: list[DeviceOp] = []
    node_id: dict[tuple[int, int], int] = {}
    for d, ops in streams.items():
        for pos, op in enumerate(ops):
            node_id[(d, pos)] = len(nodes)
            nodes.append(op)

    adj: list[list[int]] = [[] for _ in nodes]
    n_edges = 0
    for d, ops in streams.items():
        for pos in range(len(ops) - 1):
            adj[node_id[(d, pos)]].append(node_id[(d, pos + 1)])
            n_edges += 1

    send_nodes: dict[int, list[int]] = {}
    recv_nodes: dict[int, list[int]] = {}
    for d, ops in streams.items():
        for pos, op in enumerate(ops):
            if op.op == "send":
                send_nodes.setdefault(op.period, []).append(
                    node_id[(d, pos)])
            elif op.op == "recv":
                recv_nodes.setdefault(op.period, []).append(
                    node_id[(d, pos)])
    for p, snodes in send_nodes.items():
        for s in snodes:
            for r in recv_nodes.get(p, ()):
                adj[s].append(r)
                n_edges += 1

    # iterative 3-color DFS; a back edge closes a deadlock cycle
    WHITE, GRAY, BLACK = 0, 1, 2
    color = [WHITE] * len(nodes)
    parent = [-1] * len(nodes)
    for root in range(len(nodes)):
        if color[root] != WHITE:
            continue
        stack = [(root, 0)]
        color[root] = GRAY
        while stack:
            u, ei = stack[-1]
            if ei < len(adj[u]):
                stack[-1] = (u, ei + 1)
                v = adj[u][ei]
                if color[v] == WHITE:
                    color[v] = GRAY
                    parent[v] = u
                    stack.append((v, 0))
                elif color[v] == GRAY:
                    cycle = [v]
                    w = u
                    while w != v and w != -1:
                        cycle.append(w)
                        w = parent[w]
                    cycle.append(v)
                    chain = " -> ".join(
                        nodes[n].describe() for n in reversed(cycle))
                    _fail(f"communication deadlock: cyclic happens-before "
                          f"wait {chain} — every device on the cycle "
                          f"blocks on an event scheduled after its own "
                          f"wait")
            else:
                color[u] = BLACK
                stack.pop()
    return n_edges


# ------------------------------------------------------- per-device memory

def check_memory(streams: dict[int, tuple[DeviceOp, ...]], l: int,
                 fp_windows: dict[int, tuple[int, ...]],
                 check_params: bool = True) -> None:
    """Walk each device's stream with an abstract chunk-level memory state.

    Activation state per device: ``None`` (nothing live / freed) or
    ``("out", p)`` (own period-p RUN output chunk) or ``("recv", p)``
    (period-p gathered activations).  Param state per device: one live
    bit per layer whose FP window contains the device (schema-v2 chunk
    residency; disabled for v1 programs via ``check_params=False``).
    """
    for d, ops in streams.items():
        act: tuple[str, int] | None = None
        freed_at: int | None = None
        param_live = {layer: True for layer, win in fp_windows.items()
                      if d in win}
        param_freed_at: dict[int, int] = {}

        def held(a=None, _d=d):
            a = a if a is not None else act
            if a is None:
                return ("nothing (freed at period "
                        f"{freed_at})" if freed_at is not None
                        else "nothing")
            tag, p = a
            return (f"its period-{p} RUN output chunk" if tag == "out"
                    else f"the period-{p} gathered activations")

        for op in ops:
            p = op.period
            if op.op == "run":
                if check_params:
                    if op.layer not in param_live:
                        _fail(f"use-before-def: RUN period {p} on device "
                              f"{d} needs layer {op.layer}'s param chunk, "
                              f"which was never resident on this device "
                              f"(FP window of layer {op.layer} does not "
                              f"contain it)")
                    if not param_live[op.layer]:
                        _fail(f"use-after-FREE: RUN period {p} on device "
                              f"{d} reads layer {op.layer}'s param chunk, "
                              f"freed by the param FREE at period "
                              f"{param_freed_at[op.layer]} (chunk "
                              f"granularity)")
                if p == 1:
                    pass  # consumes the input batch, defined at step start
                elif p == l + 1:
                    if act != ("out", l):
                        _fail(f"use-before-def: RUN period {p} on device "
                              f"{d} is the FP->BP turnaround and expects "
                              f"the period-{l} activation chunk in place "
                              f"(Eq. 11: equal windows, no transition), "
                              f"but the device holds {held()}")
                elif act != ("recv", p - 1):
                    _fail(f"use-before-def: RUN period {p} on device {d} "
                          f"consumes the period-{p - 1} gathered "
                          f"activations, but the device holds {held()}")
                act = ("out", p)
            elif op.op == "send":
                if act is None:
                    _fail(f"use-after-FREE: SEND at period {p} on device "
                          f"{d} reads the period-{p} activation chunk "
                          f"{op.chunk}, but it was freed by the window "
                          f"FREE at period {freed_at} earlier in the "
                          f"stream (FREE before last use)")
                if act != ("out", p):
                    _fail(f"use-before-def: SEND at period {p} on device "
                          f"{d} sends the period-{p} RUN output chunk "
                          f"{op.chunk}, but the device holds {held()}")
            elif op.op == "recv":
                act = ("recv", p)
            elif op.op == "free" and op.free_kind == "window":
                if act is None:
                    _fail(f"double FREE: window FREE at period {p} on "
                          f"device {d} releases an activation chunk "
                          f"already freed at period {freed_at}")
                act = None
                freed_at = p
            elif op.op == "free" and op.free_kind == "param":
                if not check_params:
                    continue
                if op.layer not in param_live:
                    _fail(f"param FREE at period {p} on device {d}: layer "
                          f"{op.layer}'s chunk was never resident on this "
                          f"device")
                if not param_live[op.layer]:
                    _fail(f"double FREE: param FREE at period {p} on "
                          f"device {d} releases layer {op.layer}'s chunk "
                          f"already freed at period "
                          f"{param_freed_at[op.layer]} (chunk granularity)")
                param_live[op.layer] = False
                param_freed_at[op.layer] = p

        if check_params:
            leaked = sorted(layer for layer, live in param_live.items()
                            if live)
            if leaked:
                _fail(f"residency leak: device {d} ends the epoch still "
                      f"holding the param chunk(s) of layer(s) {leaked} — "
                      f"no param FREE released them")
