"""Shape/dtype abstract interpretation of a period program.

Propagates the abstract activation value ``(batch, width)`` through the
FP periods and the cotangent ``(batch, width)`` back through the BP
periods, cross-checking at every RUN:

  * the consumed width matches the layer's weight-chunk geometry
    ``(n_{i-1}+1, chunk_width)`` and the gathered output width
    ``degree * chunk_width`` reconstructs exactly ``n_i``;
  * the activation annotation matches the model contract
    (``models.fcnn.period_activation``: hidden layers sigmoid, output
    layer none) and each BP RUN differentiates the same nonlinearity its
    FP mirror applied;
  * (schema v2) the ``param_bytes`` annotations imply one consistent
    element width across all layers — and exactly
    ``cfg.bytes_per_value`` when a config is given;
  * (with a workload) the program's ``batch_size`` and ``layer_sizes``
    are the workload's — a stale or corrupted program fails here with a
    precise ``ProgramAnalysisError`` instead of a jit trace error deep
    inside shard_map.
"""

from __future__ import annotations

import math

from repro.core.onoc_model import FCNNWorkload, ONoCConfig, period_layer
from repro.exec.analysis.errors import ProgramAnalysisError
from repro.exec.program import Opcode, PeriodProgram
from repro.models.fcnn import period_activation

__all__ = ["check_shapes"]

_BPV_TOL = 1e-9


def _fail(msg: str) -> None:
    raise ProgramAnalysisError(msg)


def check_shapes(program: PeriodProgram,
                 workload: FCNNWorkload | None = None,
                 cfg: ONoCConfig | None = None) -> int:
    """Run the abstract interpreter; returns the number of RUNs checked."""
    sizes = program.layer_sizes
    l = program.l
    batch = program.batch_size
    if not isinstance(batch, int) or batch < 1:
        _fail(f"shape mismatch: program batch_size {batch!r} is not a "
              f"positive integer")

    if workload is not None:
        if tuple(int(n) for n in workload.layer_sizes) != sizes:
            _fail(f"shape mismatch: program layer_sizes {list(sizes)} != "
                  f"workload layer_sizes {list(workload.layer_sizes)}")
        if batch != workload.batch_size:
            _fail(f"shape mismatch: RUN period 1 consumes a "
                  f"(batch={batch}, n_0={sizes[0]}) activation block per "
                  f"program.batch_size, but the workload feeds batch "
                  f"{workload.batch_size} — program batch_size disagrees "
                  f"with the workload")

    runs = {i.period: i for i in program.instructions
            if i.opcode is Opcode.RUN}
    bytes_per_value: dict[int, float] = {}
    n_checked = 0

    # forward pass: abstract activation (batch, width)
    width = sizes[0]
    for p in range(1, l + 1):
        run = runs.get(p)
        if run is None:
            _fail(f"shape interpretation impossible: no RUN at period {p}")
        layer = run.layer
        if workload is not None and layer != period_layer(workload, p):
            _fail(f"shape mismatch: RUN period {p} computes layer {layer} "
                  f"!= paper period-layer {period_layer(workload, p)}")
        in_width = sizes[layer - 1]
        if in_width != width:
            _fail(f"shape mismatch: RUN period {p} multiplies a "
                  f"(batch={batch}, {width}) activation block by layer "
                  f"{layer}'s ({in_width}+1, {run.chunk_width}) weight "
                  f"chunk — inner dimensions {width} != {in_width}")
        out_width = (run.degree or 0) * (run.chunk_width or 0)
        if out_width != sizes[layer]:
            _fail(f"shape mismatch: RUN period {p} gathers degree x "
                  f"chunk_width = {run.degree} x {run.chunk_width} = "
                  f"{out_width} output columns != n_{layer} = "
                  f"{sizes[layer]}")
        want_act = period_activation(layer, l)
        if run.activation != want_act:
            _fail(f"activation mismatch: RUN period {p} (layer {layer}) "
                  f"is annotated {run.activation!r} but the model contract "
                  f"(period_activation) requires {want_act!r} — the "
                  f"executor would apply the wrong nonlinearity")
        if program.version >= 2 and run.param_bytes:
            bytes_per_value[layer] = run.param_bytes / (
                (in_width + 1) * run.chunk_width)
        width = sizes[layer]
        n_checked += 1

    # backward pass: abstract cotangent (batch, width), seeded by the loss
    cot = sizes[l]
    for p in range(l + 1, 2 * l + 1):
        run = runs.get(p)
        if run is None:
            _fail(f"shape interpretation impossible: no RUN at period {p}")
        layer = run.layer
        if workload is not None and layer != period_layer(workload, p):
            _fail(f"shape mismatch: RUN period {p} computes layer {layer} "
                  f"!= paper period-layer {period_layer(workload, p)}")
        if sizes[layer] != cot:
            _fail(f"shape mismatch: BP RUN period {p} (layer {layer}) "
                  f"consumes a (batch={batch}, {cot}) cotangent but layer "
                  f"{layer} produces n_{layer} = {sizes[layer]} outputs")
        fp = runs.get(layer)
        if fp is not None and run.activation != fp.activation:
            _fail(f"activation mismatch: BP RUN period {p} is annotated "
                  f"{run.activation!r} but its FP mirror (period {layer}) "
                  f"applied {fp.activation!r} — the backward pass would "
                  f"differentiate the wrong nonlinearity")
        cot = sizes[layer - 1]
        n_checked += 1

    # dtype: one element width across all layers, == cfg when given
    if bytes_per_value:
        widths = sorted(set(bytes_per_value.values()))
        if not math.isclose(widths[0], widths[-1], rel_tol=_BPV_TOL):
            _fail(f"dtype mismatch: param_bytes annotations imply "
                  f"inconsistent element widths across layers: "
                  f"{ {k: v for k, v in sorted(bytes_per_value.items())} }")
        if cfg is not None and not math.isclose(
                widths[0], cfg.bytes_per_value, rel_tol=_BPV_TOL):
            _fail(f"dtype mismatch: param_bytes annotations imply "
                  f"{widths[0]!r} bytes per value, but cfg.bytes_per_value "
                  f"= {cfg.bytes_per_value!r}")
    return n_checked
