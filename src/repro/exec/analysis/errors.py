"""Shared error types for the static program analyzer.

``ProgramAnalysisError`` subclasses ``ProgramValidationError`` so every
existing ``except ProgramValidationError`` site (degraded-mode replans,
compile-time guards, tests) also catches analyzer rejections — the
analyzer is a strictly stronger verifier layered on the same contract,
not a parallel error taxonomy.
"""

from __future__ import annotations

from repro.exec.validate import ProgramValidationError

__all__ = ["ProgramAnalysisError", "ProgramValidationError"]


class ProgramAnalysisError(ProgramValidationError):
    """Per-device static analysis rejected the program.

    Raised by ``exec.analysis.analyze_program`` when the per-device
    expansion, the happens-before graph, or the shape abstract
    interpreter finds a defect that the SPMD-level validator
    (``exec.validate.validate_program``) cannot see: communication
    deadlocks, swapped SEND/RECV endpoints, use-after-FREE /
    use-before-def / double-FREE at chunk granularity, and
    shape/dtype/activation mismatches against the workload.
    """
