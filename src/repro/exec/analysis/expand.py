"""Per-device expansion: one concrete instruction stream per device.

A ``PeriodProgram`` is a single SPMD program whose device-dependent
behaviour the executor resolves at run time with ``axis_index`` (see
exec/runtime.py).  That is exactly the resolution a static checker needs
to do *ahead* of time: which chunk a device computes, who it sends to,
whose chunk it expects at each RECV, which FREE drops which resident
chunk.  ``expand_program`` performs it, lowering the program into one
``DeviceOp`` stream per device on the ring:

  * window membership — a device appears in a period's stream iff the
    instruction's device set contains it;
  * chunk geometry — chunk index = the device's position in the RUN
    window (the executor's ``gathered[lay.window]`` selection: chunk j
    of a period's activation is computed by ``window[j]``);
  * SEND/RECV endpoints — a SEND's peers are the matching RECV's
    receivers; a RECV's peers are its chunk-ordered ``sources``
    (falling back to the same-period SEND's sender window for programs
    serialized before the annotation existed).

The expansion itself is deliberately mechanical — all judgement lives in
the checkers (``hb``: deadlocks/endpoints/memory, ``shapes``: abstract
interpretation) that consume the streams.
"""

from __future__ import annotations

import dataclasses

from repro.exec.program import Opcode, PeriodProgram

__all__ = ["DeviceOp", "expand_program", "n_device_ops"]


@dataclasses.dataclass(frozen=True)
class DeviceOp:
    """One device's view of one program instruction.

    ``index`` is the instruction's position in ``program.instructions``
    so every diagnostic can point back at the SPMD source.  ``chunk`` is
    the device's column-chunk index within the period window (RUN/SEND).
    ``peers`` is the resolved endpoint set: receivers for a SEND, the
    chunk-ordered source devices for a RECV.
    """

    device: int
    index: int
    op: str                             # "run" | "send" | "recv" | "free"
    period: int
    layer: int | None = None
    phase: str | None = None            # "fp" | "bp" (RUN)
    chunk: int | None = None
    chunk_width: int | None = None
    activation: str | None = None
    peers: tuple[int, ...] = ()
    free_kind: str | None = None        # "window" | "param" (FREE)
    param_bytes: float = 0.0

    def describe(self) -> str:
        tag = f"{self.op.upper()} period {self.period}"
        if self.op == "free" and self.free_kind == "param":
            tag += f" (param, layer {self.layer})"
        return f"device {self.device} {tag}"


def expand_program(program: PeriodProgram) -> dict[int, tuple[DeviceOp, ...]]:
    """Lower ``program`` into per-device streams, program order preserved.

    Every device on the ring gets a stream (idle devices an empty one),
    so downstream checks can reason about the whole mesh.
    """
    sends = {i.period: i for i in program.instructions
             if i.opcode is Opcode.SEND}
    recvs = {i.period: i for i in program.instructions
             if i.opcode is Opcode.RECV}
    streams: dict[int, list[DeviceOp]] = {
        d: [] for d in range(program.n_devices)}

    for idx, ins in enumerate(program.instructions):
        if ins.opcode is Opcode.RUN:
            for j, d in enumerate(ins.devices):
                streams[d].append(DeviceOp(
                    device=d, index=idx, op="run", period=ins.period,
                    layer=ins.layer, phase=ins.phase, chunk=j,
                    chunk_width=ins.chunk_width,
                    activation=ins.activation,
                    param_bytes=ins.param_bytes))
        elif ins.opcode is Opcode.SEND:
            recv = recvs.get(ins.period)
            peers = tuple(recv.devices) if recv is not None else ()
            for j, d in enumerate(ins.devices):
                streams[d].append(DeviceOp(
                    device=d, index=idx, op="send", period=ins.period,
                    chunk=j, peers=peers))
        elif ins.opcode is Opcode.RECV:
            send = sends.get(ins.period)
            sources = tuple(ins.sources) or (
                tuple(send.devices) if send is not None else ())
            for d in ins.devices:
                streams[d].append(DeviceOp(
                    device=d, index=idx, op="recv", period=ins.period,
                    peers=sources))
        elif ins.opcode is Opcode.FREE:
            kind = "window" if ins.layer is None else "param"
            for d in ins.devices:
                streams[d].append(DeviceOp(
                    device=d, index=idx, op="free", period=ins.period,
                    layer=ins.layer, free_kind=kind,
                    param_bytes=ins.param_bytes))
    return {d: tuple(ops) for d, ops in streams.items()}


def n_device_ops(streams: dict[int, tuple[DeviceOp, ...]]) -> int:
    return sum(len(ops) for ops in streams.values())
