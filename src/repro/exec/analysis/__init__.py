"""Static program analysis for compiled period programs (ISSUE 9).

``exec.validate`` checks a ``PeriodProgram`` at the SPMD/ledger level;
this package expands the program to one concrete instruction stream per
device and verifies what only that view can see:

  * ``expand``  — the per-device expander (window membership, chunk
    geometry, SEND/RECV endpoints resolved statically);
  * ``hb``      — happens-before graph over the streams: communication
    deadlocks (cyclic waits), unmatched/misordered SEND/RECV endpoints,
    per-device use-before-def, use-after-FREE and double-FREE at chunk
    granularity;
  * ``shapes``  — shape/dtype abstract interpretation of the activation
    and cotangent flow, cross-checked against the workload;
  * ``corpus``  — a seeded corruption corpus that the validator passes
    but the analyzer must reject (regression fixture for all of the
    above).

Entry point::

    report = analyze_program(program, workload, cfg, level="full")

``level`` trades coverage for time: ``"off"`` skips analysis entirely,
``"fast"`` runs the validator's structural pre-pass plus the per-device
expansion and happens-before/memory checks, ``"full"`` adds the cost
contract (workload+cfg) and the shape abstract interpreter.  It runs at
compile time (``repro.exec.compile(analyze=...)``) and after every
replan (``runtime.degraded``).  All rejections raise
``ProgramAnalysisError`` — a subclass of ``ProgramValidationError``, so
existing handlers keep working.
"""

from __future__ import annotations

import dataclasses

from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.exec.analysis.corpus import (  # noqa: F401
    CorruptedProgram,
    corruption_corpus,
)
from repro.exec.analysis.errors import ProgramAnalysisError  # noqa: F401
from repro.exec.analysis.expand import (  # noqa: F401
    DeviceOp,
    expand_program,
    n_device_ops,
)
from repro.exec.analysis.hb import (  # noqa: F401
    check_endpoints,
    check_happens_before,
    check_memory,
)
from repro.exec.analysis.shapes import check_shapes  # noqa: F401
from repro.exec.program import PeriodProgram
from repro.exec.validate import validate_program

__all__ = [
    "AnalysisReport",
    "CorruptedProgram",
    "DeviceOp",
    "ProgramAnalysisError",
    "analyze_program",
    "check_endpoints",
    "check_happens_before",
    "check_memory",
    "check_shapes",
    "corruption_corpus",
    "expand_program",
    "n_device_ops",
]

LEVELS = ("off", "fast", "full")


@dataclasses.dataclass(frozen=True)
class AnalysisReport:
    """What the analyzer looked at — sized for benchmark reporting."""

    level: str
    n_devices: int
    n_instructions: int
    n_device_ops: int
    n_hb_edges: int
    checks: tuple[str, ...]


def analyze_program(
    program: PeriodProgram,
    workload: FCNNWorkload | None = None,
    cfg: ONoCConfig | None = None,
    backend=None,
    level: str = "full",
) -> AnalysisReport | None:
    """Statically analyze ``program``; raise on the first defect found.

    Check order (first failure wins): the SPMD validator as a fast
    pre-pass, then endpoint matching, the happens-before graph, the
    per-device memory walk, and (``"full"`` only) the shape/dtype
    abstract interpreter.  Returns an ``AnalysisReport`` (``None`` at
    level ``"off"``).
    """
    if level not in LEVELS:
        raise ValueError(f"analyze level must be one of {LEVELS}, "
                         f"got {level!r}")
    if level == "off":
        return None

    checks = ["validate"]
    if level == "full":
        validate_program(program, workload, cfg, backend=backend)
    else:
        validate_program(program)

    streams = expand_program(program)
    check_endpoints(program)
    n_edges = check_happens_before(streams)
    fp_windows = {r.layer: r.devices for r in program.runs("fp")}
    check_memory(streams, l=program.l, fp_windows=fp_windows,
                 check_params=program.version >= 2)
    checks += ["expand", "endpoints", "happens-before", "memory"]

    if level == "full":
        check_shapes(program, workload, cfg)
        checks.append("shapes")

    return AnalysisReport(
        level=level,
        n_devices=program.n_devices,
        n_instructions=len(program.instructions),
        n_device_ops=n_device_ops(streams),
        n_hb_edges=n_edges,
        checks=tuple(checks),
    )
