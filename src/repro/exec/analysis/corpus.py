"""Seeded corruption corpus: programs the validator passes but the
analyzer must reject.

Each corruption targets a blind spot of ``validate_program``'s SPMD-level
set/ledger checks (which are order-insensitive within a period and never
read ``sources``, ``batch_size`` or ``activation``):

  * ``deadlocked-send-cycle``   — swap a transition period's SEND and
    RECV in the instruction stream.  Any device in both windows then
    posts its blocking RECV (which waits on *all* senders, itself
    included) before its own SEND: a happens-before cycle, i.e. a
    communication deadlock.
  * ``swapped-recv-source``     — rotate a RECV's chunk-ordered
    ``sources``: every chunk is still supplied by a legitimate sender
    (the multiset matches, so nothing hangs), but each receiver gathers
    the *wrong device's* chunk — silent wrong numerics at run time.
  * ``free-before-last-use``    — move one leaving device's window FREE
    before the same period's SEND, on that device only: its stream frees
    the activation chunk the SEND is about to read (use-after-FREE).
  * ``shape-mismatched-run``    — corrupt ``batch_size`` (the validator
    prices costs from the workload argument, never from the program's
    own batch) and, separately, flip a hidden-layer RUN's activation
    annotation — both caught only by the shape abstract interpreter.

``corruption_corpus`` derives all of them from one valid program with a
seeded RNG (reproducible; the seed picks among eligible periods), and
every entry records the regex its ``ProgramAnalysisError`` must match.
"""

from __future__ import annotations

import dataclasses
import random

from repro.exec.program import Instruction, Opcode, PeriodProgram

__all__ = ["CorruptedProgram", "corruption_corpus"]


@dataclasses.dataclass(frozen=True)
class CorruptedProgram:
    """One corpus entry: a corrupted program plus the expected rejection."""

    name: str
    description: str
    program: PeriodProgram
    match: str          # regex the ProgramAnalysisError message must match


def _with_instrs(program: PeriodProgram, instrs) -> PeriodProgram:
    return dataclasses.replace(program, instructions=tuple(instrs))


def _deadlocked_send_cycle(program, rng) -> CorruptedProgram | None:
    instrs = list(program.instructions)
    sends = {i.period: idx for idx, i in enumerate(instrs)
             if i.opcode is Opcode.SEND}
    recvs = {i.period: idx for idx, i in enumerate(instrs)
             if i.opcode is Opcode.RECV}
    eligible = [p for p in sends if p in recvs and
                set(instrs[sends[p]].devices)
                & set(instrs[recvs[p]].devices)]
    if not eligible:
        return None
    p = rng.choice(sorted(eligible))
    si, ri = sends[p], recvs[p]
    instrs[si], instrs[ri] = instrs[ri], instrs[si]
    overlap = sorted(set(program.instructions[si].devices)
                     & set(program.instructions[ri].devices))
    return CorruptedProgram(
        name="deadlocked-send-cycle",
        description=(f"period-{p} RECV scheduled before its SEND; devices "
                     f"{overlap} are in both windows, so each waits on its "
                     f"own later SEND"),
        program=_with_instrs(program, instrs),
        match="deadlock",
    )


def _swapped_recv_source(program, rng) -> CorruptedProgram | None:
    instrs = list(program.instructions)
    eligible = [idx for idx, i in enumerate(instrs)
                if i.opcode is Opcode.RECV and len(set(i.sources)) > 1]
    if not eligible:
        return None
    idx = rng.choice(eligible)
    ins = instrs[idx]
    k = rng.randrange(1, len(ins.sources))
    rotated = ins.sources[k:] + ins.sources[:k]
    instrs[idx] = dataclasses.replace(ins, sources=rotated)
    return CorruptedProgram(
        name="swapped-recv-source",
        description=(f"period-{ins.period} RECV sources rotated by {k}: "
                     f"{list(ins.sources)} -> {list(rotated)}; every chunk "
                     f"still has a sender, but the wrong one"),
        program=_with_instrs(program, instrs),
        match="swapped RECV source",
    )


def _free_before_last_use(program, rng) -> CorruptedProgram | None:
    instrs = list(program.instructions)
    sends = {i.period: idx for idx, i in enumerate(instrs)
             if i.opcode is Opcode.SEND}
    eligible = [idx for idx, i in enumerate(instrs)
                if i.opcode is Opcode.FREE and i.layer is None
                and i.period in sends
                and set(i.devices) <= set(instrs[sends[i.period]].devices)]
    if not eligible:
        return None
    idx = rng.choice(eligible)
    free = instrs[idx]
    victim = rng.choice(sorted(free.devices))
    # split the FREE: the victim's half moves before the SEND, the rest
    # (if any) stays in place — the corruption is on one device only
    rest = tuple(d for d in free.devices if d != victim)
    del instrs[idx]
    if rest:
        instrs.insert(idx, dataclasses.replace(free, devices=rest))
    instrs.insert(sends[free.period],
                  dataclasses.replace(free, devices=(victim,)))
    return CorruptedProgram(
        name="free-before-last-use",
        description=(f"device {victim}'s window FREE at period "
                     f"{free.period} moved before the SEND that still "
                     f"reads its activation chunk"),
        program=_with_instrs(program, instrs),
        match="use-after-FREE",
    )


def _shape_mismatched_batch(program, rng) -> CorruptedProgram:
    factor = rng.choice([2, 3, 5])
    return CorruptedProgram(
        name="shape-mismatched-run-batch",
        description=(f"batch_size corrupted {program.batch_size} -> "
                     f"{program.batch_size * factor}; the validator prices "
                     f"costs from the workload argument and never reads it"),
        program=dataclasses.replace(
            program, batch_size=program.batch_size * factor),
        match="batch",
    )


def _shape_mismatched_activation(program, rng) -> CorruptedProgram | None:
    instrs = list(program.instructions)
    eligible = [idx for idx, i in enumerate(instrs)
                if i.opcode is Opcode.RUN and i.phase == "fp"
                and i.activation == "sigmoid"]
    if not eligible:
        return None
    idx = rng.choice(eligible)
    ins = instrs[idx]
    wrong = rng.choice(["none", "relu", "tanh"])
    instrs[idx] = dataclasses.replace(ins, activation=wrong)
    return CorruptedProgram(
        name="shape-mismatched-run-activation",
        description=(f"period-{ins.period} RUN activation flipped "
                     f"'sigmoid' -> {wrong!r}"),
        program=_with_instrs(program, instrs),
        match="activation mismatch",
    )


_BUILDERS = (
    _deadlocked_send_cycle,
    _swapped_recv_source,
    _free_before_last_use,
    _shape_mismatched_batch,
    _shape_mismatched_activation,
)


def corruption_corpus(program: PeriodProgram,
                      seed: int = 0) -> tuple[CorruptedProgram, ...]:
    """Derive the corpus from one valid ``program``.

    Raises ``ValueError`` when the program offers no eligible site for
    some corruption (e.g. a schedule with no window overlap anywhere) —
    tests should feed a program where all entries are constructible.
    """
    out = []
    for builder in _BUILDERS:
        entry = builder(program, random.Random(seed))
        if entry is None:
            raise ValueError(
                f"program offers no eligible corruption site for "
                f"{builder.__name__}")
        out.append(entry)
    return tuple(out)
