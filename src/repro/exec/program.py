"""Schedule compiler: planner plan + mapping -> static instruction program.

A ``PeriodProgram`` is the executable form of one training epoch of the
paper's fine-grained model: for each of the 2l periods, a RUN instruction
(the fused per-shard math), and between consecutive periods the SEND/RECV
pair that moves activations from one period's core window to the next's,
plus FREE for cores that leave the active window.  The instruction set
follows alpa's decentralized static runtime (RUN/SEND/RECV/FREE), with one
difference: alpa compiles a per-worker program, while we compile a single
SPMD program whose device-dependent behaviour the executor resolves with
``axis_index`` (see exec/runtime.py).

Two levels of placement coexist in one program:

  * the **paper level** — the Lemma-1 core counts m_i* on the cfg.m-core
    ring, placed by the chosen mapping strategy.  All cost annotations
    (``cost_s`` on RUN and SEND) are priced at this level with exactly the
    conventions of ``core.simulator.simulate_epoch``: 2l-2 transitions, at
    periods {1..2l-1} minus {l}; on ONoC the period-1 hand-off costs zero
    (Eq. 6 folds it into Period-0 loading) though its traffic is recorded.
    ``program.compute_s``/``comm_s`` therefore agree *exactly* with the
    simulator's EpochTrace — the closed-form model becomes an executable
    contract (pinned by tests/test_exec_program.py).

  * the **device level** — the same schedule re-placed on the executor's
    n-device ring: per FP period a mesh-feasible degree d_i (a divisor of
    both n_devices and the layer width n_i, log-closest to the planner's
    degree), and a device window produced by running the *same* mapping
    strategy (Algorithm 1 et al.) on the n-device ring.  RUN carries the
    window and column-chunk geometry the executor needs; FREE lists the
    devices whose chunks are dropped at each transition.

Programs are plain data: serializable via ``to_json``/``from_json`` so a
compiled schedule can be shipped to workers or diffed across PRs.

Schema v2 makes parameter **residency** explicit (the weight-sharded
executor, exec/runtime.py): every RUN carries ``param_bytes`` — the bytes
of the (n_{i-1}+1) x (n_i/d_i) weight+bias column chunk each window device
holds for that period — and each layer's chunks are released by a *param*
FREE (``layer`` set, ``param_bytes`` set) scheduled immediately after the
chunk's last use, the layer's BP mirror period 2l-i+1 (Eq. 11).  The
original window FREEs (``layer`` is None) keep their PR-6 meaning: a
device leaving the *active* window drops its activations but keeps its
weight chunks for BP.  ``exec.validate`` checks the byte ledger drains to
exactly zero and that no RUN executes on freed chunks;
``exec.residency.ResidencyTracker`` turns the annotations into a
per-device live-bytes timeline.
"""

from __future__ import annotations

import dataclasses
import enum
import json
import math

from repro.core.allocation import Mapping, MappingStrategy, map_cores
from repro.core.onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    compute_time,
    period_layer,
)
from repro.core.planner import FCNNPlan, plan_fcnn, ring_mesh_axes
from repro.core.simulator import ONoCBackend
from repro.models.fcnn import period_activation

__all__ = [
    "Opcode",
    "Instruction",
    "PeriodProgram",
    "compile_program",
    "compile_fcnn_program",
    "snap_to_ring_degree",
]

_JSON_VERSION = 2        # v2: residency annotations (param_bytes, param FREEs)


class Opcode(str, enum.Enum):
    RUN = "run"
    SEND = "send"
    RECV = "recv"
    FREE = "free"


@dataclasses.dataclass(frozen=True)
class Instruction:
    """One static instruction of the per-period program.

    ``devices`` is the instruction's device set on the executor ring: the
    active window for RUN, senders for SEND, receivers for RECV, released
    devices for FREE.  ``cost_s`` is the paper-level cost annotation
    (compute_time for RUN, the backend transition time for SEND; RECV and
    FREE are free — the transition is charged once, on the sender side,
    matching the simulator's one-comm_s-per-transition convention).
    """

    opcode: Opcode
    period: int
    devices: tuple[int, ...] = ()
    cost_s: float = 0.0
    # RUN fields (``layer`` is also set on param FREEs, see below)
    layer: int | None = None
    phase: str | None = None            # "fp" | "bp"
    activation: str | None = None
    onoc_cores: int | None = None       # paper-level m_i*
    degree: int | None = None           # device-level d_i
    chunk_width: int | None = None      # n_layer // d_i output columns
    # SEND annotations (from the backend's TransitionTraffic)
    bytes_per_sender: float = 0.0
    slots: int = 0
    hop_bytes: float = 0.0
    # residency annotation (schema v2): per-device bytes of the layer's
    # weight+bias column chunk — held by each window device for a RUN,
    # released by a param FREE (opcode FREE with ``layer`` set)
    param_bytes: float = 0.0
    # RECV endpoint annotation: chunk j of the gathered activation comes
    # from device ``sources[j]`` — the chunk-ordered sender window of the
    # matching SEND.  Empty on pre-analysis programs (the analyzer then
    # derives it from the SEND at the same period).
    sources: tuple[int, ...] = ()

    @classmethod
    def RUN(cls, period, layer, phase, activation, onoc_cores, degree,
            chunk_width, window, cost_s, param_bytes=0.0):
        return cls(opcode=Opcode.RUN, period=period, devices=tuple(window),
                   cost_s=cost_s, layer=layer, phase=phase,
                   activation=activation, onoc_cores=onoc_cores,
                   degree=degree, chunk_width=chunk_width,
                   param_bytes=param_bytes)

    @classmethod
    def SEND(cls, period, senders, cost_s, bytes_per_sender, slots,
             hop_bytes):
        return cls(opcode=Opcode.SEND, period=period, devices=tuple(senders),
                   cost_s=cost_s, bytes_per_sender=bytes_per_sender,
                   slots=slots, hop_bytes=hop_bytes)

    @classmethod
    def RECV(cls, period, receivers, sources=()):
        return cls(opcode=Opcode.RECV, period=period,
                   devices=tuple(receivers), sources=tuple(sources))

    @classmethod
    def FREE(cls, period, released, layer=None, param_bytes=0.0):
        """``layer`` is None for a window FREE (a device leaves the active
        window, dropping activations); set for a param FREE (the released
        devices drop their ``param_bytes`` chunk of that layer)."""
        return cls(opcode=Opcode.FREE, period=period,
                   devices=tuple(released), layer=layer,
                   param_bytes=param_bytes)


@dataclasses.dataclass(frozen=True)
class PeriodProgram:
    """A compiled epoch schedule: static instructions + cost annotations."""

    layer_sizes: tuple[int, ...]
    batch_size: int
    strategy: str
    backend: str
    n_devices: int
    onoc_cores: tuple[int, ...]         # paper m_i*, FP periods 1..l
    degrees: tuple[int, ...]            # executor degree d_i, FP periods
    instructions: tuple[Instruction, ...]
    version: int = _JSON_VERSION        # schema version (v2: residency)

    @property
    def l(self) -> int:  # noqa: E743 — paper notation
        return len(self.layer_sizes) - 1

    def runs(self, phase: str | None = None) -> list[Instruction]:
        return [i for i in self.instructions if i.opcode is Opcode.RUN
                and (phase is None or i.phase == phase)]

    def sends(self) -> list[Instruction]:
        return [i for i in self.instructions if i.opcode is Opcode.SEND]

    def frees(self, kind: str | None = None) -> list[Instruction]:
        """FREE instructions: all (None), only window FREEs (``"window"``,
        layer is None) or only param FREEs (``"param"``, layer set)."""
        fs = [i for i in self.instructions if i.opcode is Opcode.FREE]
        if kind == "window":
            return [f for f in fs if f.layer is None]
        if kind == "param":
            return [f for f in fs if f.layer is not None]
        if kind is not None:
            raise ValueError(f"kind must be None, 'window' or 'param', "
                             f"got {kind!r}")
        return fs

    @property
    def compute_s(self) -> float:
        """Paper-level epoch compute — equals EpochTrace.compute_s."""
        return float(sum(i.cost_s for i in self.runs()))

    @property
    def comm_s(self) -> float:
        """Paper-level epoch comm — equals EpochTrace.comm_s."""
        return float(sum(i.cost_s for i in self.sends()))

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    def transition_schedule(self) -> list[int]:
        """Periods that send — must be {1..2l-1} \\ {l} (2l-2 of them)."""
        return [i.period for i in self.sends()]

    def param_bytes_per_device(self) -> dict[int, float]:
        """Per-device resident chunk bytes of each FP layer (1-based)."""
        return {r.layer: r.param_bytes for r in self.runs(phase="fp")}

    def device_stream(self, device: int) -> tuple[Instruction, ...]:
        """The instructions that involve ``device``, in program order.

        This is the raw per-device *view* (the SPMD instruction filtered
        by membership in ``devices``); ``exec.analysis.expand_program``
        lowers it further into concrete per-device ops with resolved
        chunk indices and SEND/RECV endpoints.
        """
        if not 0 <= device < self.n_devices:
            raise ValueError(
                f"device {device} out of range 0..{self.n_devices - 1}")
        return tuple(i for i in self.instructions if device in i.devices)

    def device_streams(self) -> dict[int, tuple[Instruction, ...]]:
        """``device_stream`` for every device on the ring (idle devices
        map to an empty stream)."""
        return {d: self.device_stream(d) for d in range(self.n_devices)}

    def to_json(self) -> str:
        d = {
            "version": self.version,
            "layer_sizes": list(self.layer_sizes),
            "batch_size": self.batch_size,
            "strategy": self.strategy,
            "backend": self.backend,
            "n_devices": self.n_devices,
            "onoc_cores": list(self.onoc_cores),
            "degrees": list(self.degrees),
            "instructions": [
                {**dataclasses.asdict(ins), "opcode": ins.opcode.value,
                 "devices": list(ins.devices)}
                for ins in self.instructions
            ],
        }
        return json.dumps(d)

    @classmethod
    def from_json(cls, s: str) -> "PeriodProgram":
        """Load a serialized program.  v1 (PR 6, no residency annotations)
        loads with zeroed ``param_bytes`` and no param FREEs — the
        validator skips the residency ledger for version < 2, and the
        sharded executor refuses such programs (recompile to upgrade)."""
        d = json.loads(s)
        version = d.get("version")
        if version not in (1, _JSON_VERSION):
            raise ValueError(f"unsupported program version {version}")
        instrs = tuple(
            Instruction(**{**i, "opcode": Opcode(i["opcode"]),
                           "devices": tuple(i["devices"]),
                           "sources": tuple(i.get("sources", ()))})
            for i in d["instructions"]
        )
        return cls(
            layer_sizes=tuple(d["layer_sizes"]),
            batch_size=int(d["batch_size"]),
            strategy=d["strategy"],
            backend=d["backend"],
            n_devices=int(d["n_devices"]),
            onoc_cores=tuple(d["onoc_cores"]),
            degrees=tuple(d["degrees"]),
            instructions=instrs,
            version=int(version),
        )


def snap_to_ring_degree(target: int, n_devices: int, layer_width: int) -> int:
    """Largest-feasibility snap of a planner degree onto an n-device ring.

    Feasible executor degrees divide both ``n_devices`` (so the all-gather
    chunk layout is uniform) and ``layer_width`` (the paper's even-mapping
    constraint, Eq. 4 with an exact ceiling).  Picks the feasible degree
    log-closest to ``target`` (ratio-symmetric, like planner._snap_degree),
    preferring the larger on ties.
    """
    cands = [d for d in range(1, n_devices + 1)
             if n_devices % d == 0 and layer_width % d == 0]
    return min(cands, key=lambda d: (abs(math.log(d / max(target, 1))), -d))


def compile_program(
    plan: FCNNPlan,
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    n_devices: int,
    backend=None,
    validate: bool = True,
) -> PeriodProgram:
    """Lower a planner plan + its mapping into a PeriodProgram.

    ``plan.mapping`` supplies the paper-level windows (m_i* cores placed on
    the cfg.m ring by the chosen strategy) that price every instruction;
    the same strategy re-run on the n-device ring (``map_cores`` with
    m=n_devices) supplies the executor windows, so FM/RRM/ORRM remapping is
    *executed*, not just priced.

    Every emitted program is statically verified (``exec.validate``) before
    it is returned — schedule invariants plus the cost contract against the
    simulator — so a miscompiled or corrupted schedule is a hard error at
    compile time, never silent wrong numerics at execution time.  Pass
    ``validate=False`` only to construct intentionally-broken programs
    (validator tests).
    """
    backend = backend or ONoCBackend()
    l = workload.l
    if len(plan.periods) != l:
        raise ValueError(f"plan has {len(plan.periods)} periods, need {l}")
    if n_devices < 1:
        raise ValueError("n_devices >= 1")

    paper_mapping: Mapping = plan.mapping
    stars = tuple(p.onoc_cores for p in plan.periods)

    degrees = tuple(
        snap_to_ring_degree(p.degree, n_devices, workload.n(i))
        for i, p in enumerate(plan.periods, start=1)
    )
    exec_mapping = map_cores(
        workload, dataclasses.replace(cfg, m=n_devices),
        plan.strategy, list(degrees))

    instrs: list[Instruction] = []
    for i in range(1, 2 * l + 1):
        layer = period_layer(workload, i)
        phase = "fp" if i <= l else "bp"
        window = exec_mapping.window(i)
        d_i = len(window)
        m_star = len(paper_mapping.window(i))
        chunk_width = workload.n(layer) // d_i
        # per-device residency: the (n_{layer-1}+1) x chunk_width
        # weight+bias column chunk each window device holds (schema v2)
        chunk_bytes = float(
            (workload.n(layer - 1) + 1) * chunk_width * cfg.bytes_per_value)
        instrs.append(Instruction.RUN(
            period=i, layer=layer, phase=phase,
            activation=period_activation(layer, l),
            onoc_cores=m_star, degree=d_i,
            chunk_width=chunk_width, window=window,
            cost_s=compute_time(workload, cfg, i, m_star),
            param_bytes=chunk_bytes,
        ))
        if i == 2 * l:
            instrs.append(Instruction.FREE(period=i, released=window))
            instrs.append(Instruction.FREE(
                period=i, released=window, layer=layer,
                param_bytes=chunk_bytes))
            break
        if i != l:  # period l is the FP->BP turnaround: data stays in place
            tr = backend.transition_time(workload, cfg, i, paper_mapping)
            comm_s = tr.comm_s
            if backend.name == "onoc" and i == 1:
                comm_s = 0.0  # Eq. (6): g(m_1)=0, folded into Period-0 load
            instrs.append(Instruction.SEND(
                period=i, senders=window, cost_s=comm_s,
                bytes_per_sender=tr.bytes_per_sender, slots=tr.slots,
                hop_bytes=tr.hop_bytes,
            ))
            instrs.append(Instruction.RECV(
                period=i, receivers=exec_mapping.window(i + 1),
                sources=window))
        released = tuple(sorted(
            set(window) - set(exec_mapping.window(i + 1))))
        if released:
            instrs.append(Instruction.FREE(period=i, released=released))
        if phase == "bp":
            # the BP mirror period 2l-layer+1 is the chunk's last use
            # (Eq. 11): wgrad done, the layer's params are dead this epoch
            instrs.append(Instruction.FREE(
                period=i, released=window, layer=layer,
                param_bytes=chunk_bytes))

    program = PeriodProgram(
        layer_sizes=tuple(int(n) for n in workload.layer_sizes),
        batch_size=workload.batch_size,
        strategy=MappingStrategy(plan.strategy).value,
        backend=backend.name,
        n_devices=n_devices,
        onoc_cores=stars,
        degrees=degrees,
        instructions=tuple(instrs),
    )
    if validate:
        from repro.exec.validate import validate_program
        validate_program(program, workload, cfg, backend=backend)
    return program


def compile_fcnn_program(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    n_devices: int,
    strategy: MappingStrategy | str = MappingStrategy.ORRM,
    backend=None,
) -> PeriodProgram:
    """Plan + compile in one call, on the divisor-complete ring mesh.

    ``ring_mesh_axes(n_devices)`` exposes every divisor of n_devices as a
    feasible planning degree, so the planner's snap and the compiler's
    ring snap agree.
    """
    plan = plan_fcnn(workload, cfg, ring_mesh_axes(n_devices),
                     strategy=strategy)
    return compile_program(plan, workload, cfg, n_devices, backend=backend)
