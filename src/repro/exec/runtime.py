"""Executor: interpret a PeriodProgram under ``shard_map`` on a device mesh.

The program is a static SPMD schedule; every device runs the same
interpretation loop and resolves its role per period from
``jax.lax.axis_index`` against the program's device windows.  Lowering of
the instruction set to mesh operations:

  RUN (fp, layer i)   each device in the period's window computes one
                      column chunk of layer i — ``ops.fcnn_layer`` on the
                      (B, n_{i-1}) activation and its (n_{i-1}, n_i/d_i)
                      weight slice, i.e. the fused Pallas kernel on TPU and
                      the jnp oracle / interpreted kernel elsewhere.
                      Devices outside the window redundantly compute the
                      window head's chunk; their output is never selected
                      (see FREE) so it is dead code to XLA.
  SEND + RECV (fp)    one ``jax.lax.all_gather`` over the ring axis plus a
                      static window-ordered selection: chunk j of the next
                      activation comes from device window[j].  This is the
                      paper's inter-period WDM broadcast: senders are the
                      current window, receivers the next.
  FREE                devices released at a transition simply stop
                      contributing: their chunks are not selected, so both
                      their forward values and their gradients are exactly
                      zero-influence from that period on.
  RUN/SEND/RECV (bp)  realized by JAX AD, exactly as the model docstring
                      promises: differentiating the interpreted forward
                      turns each all_gather into its transpose
                      (psum_scatter — the BP reduce-scatter, "senders of
                      period i are receivers of period 2l-i+1", Eq. 11) and
                      runs the fused dgrad/wgrad kernels of
                      ``kernels.ops.fcnn_layer``'s custom_vjp as the BP
                      RUNs.  The BP instructions in the program are the
                      cost-annotated contract for what AD emits.

The loss period (the FP->BP turnaround at period l) gathers the logit
chunks within the final window and evaluates the fused
``ops.softmax_xent``; the program schedules no transition there (the
paper keeps data in place at the turnaround, g(m_l) = 0).

Two **residency** modes select the params-layout contract (ISSUE 8):

  replicated   the PR-6 oracle.  Params and batch enter fully replicated
               (``PartitionSpec()``); every device holds the full model and
               slices its chunk per period; FREE is a cost annotation.
  sharded      the weight-sharded path (schema-v2 programs only).  Params
               enter *stacked*: layer i is ``w: (n_dev, n_{i-1}, n_i/d_i)``
               / ``b: (n_dev, n_i/d_i)``, sharded ``P(axis)`` on the
               leading device axis, so each device materializes exactly one
               column chunk per layer — its own chunk if it is in the
               layer's window (``shard_params`` places chunk
               ``owner_chunk[j]`` on device j), zeros otherwise.  Weights
               are never re-gathered whole: only *activations* move
               (all_gather of the (B, n_i/d_i) period output).  Off-window
               zero chunks produce unselected outputs, therefore zero
               cotangents, therefore zero grads — plain elementwise
               optimizers keep them exactly zero.  Per-device live
               parameter bytes match the program's residency annotations
               (``exec.residency.ResidencyTracker``): ~1/d of the
               replicated model per degree-d period.

Numerics: in both modes each chunk of each weight matrix is computed by
exactly one selected device with identical inputs, so the sharded path is
bit-identical to the replicated oracle — losses, grads and optimizer
trajectories match with zero tolerance (pinned by
tests/test_exec_residency.py on the 8-device CPU ring, ref and
pallas_interpret kernels; tests/test_exec_runtime.py pins the oracle
against the single-device fused path).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.exec.program import PeriodProgram
from repro.kernels import ops
from repro.optim.optimizers import Optimizer

Params = dict[str, Any]

__all__ = ["ProgramExecutor", "build_train_step"]


@dataclasses.dataclass(frozen=True)
class _PeriodLayout:
    """Static per-FP-period geometry precomputed from RUN instructions."""

    layer: int                 # 1-based
    width: int                 # output columns per chunk (n_i / d_i)
    n_out: int                 # n_i
    activation: str
    window: np.ndarray         # device id of chunk j, shape (d_i,)
    owner_chunk: np.ndarray    # chunk index each device computes, shape (n,)


class ProgramExecutor:
    """Interprets a compiled PeriodProgram on a 1-axis device mesh.

    ``loss_fn(params, batch)`` has the same signature and semantics as
    ``models.fcnn.loss_fn`` and is an ordinary traceable JAX function —
    jit, grad and optimizers compose with it as usual.
    """

    def __init__(self, program: PeriodProgram, mesh: Mesh,
                 kernel_mode: str | None = None,
                 residency: str = "replicated"):
        if len(mesh.axis_names) != 1:
            raise ValueError(
                f"executor mesh must have one (ring) axis, got "
                f"{mesh.axis_names}")
        n = mesh.devices.size
        if n != program.n_devices:
            raise ValueError(
                f"program compiled for {program.n_devices} devices, mesh "
                f"has {n}")
        if residency not in ("replicated", "sharded"):
            raise ValueError(
                f"residency must be 'replicated' or 'sharded', got "
                f"{residency!r}")
        if residency == "sharded" and program.version < 2:
            raise ValueError(
                f"sharded residency needs a schema-v2 program with "
                f"residency annotations; this one is v{program.version} "
                f"— recompile with compile_program")
        self.program = program
        self.mesh = mesh
        self.axis = mesh.axis_names[0]
        self.residency = residency
        # Freeze the kernel dispatch for the program's whole lifetime so
        # every period of every step takes the same path.
        self.kernel_mode = ops.resolve_mode(kernel_mode)
        # Byte-level accounting of the layout this executor runs under.
        from repro.exec.residency import ResidencyTracker
        self.tracker = ResidencyTracker(program, mode=residency)

        self._layout: list[_PeriodLayout] = []
        for run in program.runs(phase="fp"):
            window = np.asarray(run.devices, dtype=np.int32)
            owner = np.zeros(n, dtype=np.int32)
            owner[window] = np.arange(len(window), dtype=np.int32)
            self._layout.append(_PeriodLayout(
                layer=run.layer, width=run.chunk_width,
                n_out=program.layer_sizes[run.layer],
                activation=run.activation, window=window,
                owner_chunk=owner,
            ))

        self._rebuild()

    def _rebuild(self) -> None:
        if self.residency == "sharded":
            body = self._device_program_sharded
            pspec = self.param_spec()
        else:
            body = self._device_program
            pspec = P()
        self._sharded = shard_map(
            body, mesh=self.mesh,
            in_specs=(pspec, P(), P()), out_specs=P(),
            # loss is replicated by construction (identical full logits on
            # every device after the final gather); collective use below is
            # beyond what the static replication checker can verify.
            check_rep=False,
        )

    def degrade(self, mode: str = "ref") -> str:
        """Graceful degradation: swap the kernel dispatch (typically fused
        Pallas -> jnp reference path) after a kernel failure and rebuild
        the sharded interpreter.  Returns the previous mode.  Callers
        holding a jitted step around the old ``loss_fn`` must rebuild it —
        the degraded-mode runner (runtime/degraded.py) does, and records
        the fallback in its FaultReport."""
        previous = self.kernel_mode
        self.kernel_mode = ops.resolve_mode(mode)
        self._rebuild()
        return previous

    # ------------------------------------------------------------- interpret

    def _device_program(self, params: Params, x: jax.Array,
                        y: jax.Array) -> jax.Array:
        """One device's view of the program: FP RUNs + transitions + loss."""
        me = jax.lax.axis_index(self.axis)
        h = x
        batch = x.shape[0]
        for lay in self._layout:
            lp = params["layers"][lay.layer - 1]
            # RUN: this device's column chunk of W/b (freed devices shadow
            # the window head's chunk; their result is never selected).
            chunk = jnp.asarray(lay.owner_chunk)[me]
            w_loc = jax.lax.dynamic_slice_in_dim(
                lp["w"], chunk * lay.width, lay.width, axis=1)
            b_loc = jax.lax.dynamic_slice_in_dim(
                lp["b"], chunk * lay.width, lay.width, axis=0)
            y_loc = ops.fcnn_layer(h, w_loc, b_loc, lay.activation,
                                   force=self.kernel_mode)
            # SEND/RECV (or the period-l turnaround gather): one collective;
            # chunk j of the next activation comes from device window[j].
            gathered = jax.lax.all_gather(y_loc, self.axis)   # (n, B, width)
            h = jnp.moveaxis(gathered[lay.window], 0, 1)      # (B, d, width)
            h = h.reshape(batch, lay.n_out)
        return ops.softmax_xent(h, y, force=self.kernel_mode)

    def _device_program_sharded(self, params: Params, x: jax.Array,
                                y: jax.Array) -> jax.Array:
        """Sharded-residency view: params arrive pre-chunked — this
        device's block of the stacked layout is its resident column chunk
        (zeros off-window), so RUN needs no slice and the weights are
        never re-gathered whole; only the (B, width) activations move."""
        h = x
        batch = x.shape[0]
        for lay in self._layout:
            lp = params["layers"][lay.layer - 1]
            w_loc = lp["w"][0]                    # (n_in, width) chunk
            b_loc = lp["b"][0]                    # (width,)
            y_loc = ops.fcnn_layer(h, w_loc, b_loc, lay.activation,
                                   force=self.kernel_mode)
            # Same window-ordered selection as the oracle: chunk j of the
            # next activation comes from device window[j], whose stacked
            # slot holds exactly chunk j (shard_params' placement).
            gathered = jax.lax.all_gather(y_loc, self.axis)   # (n, B, width)
            h = jnp.moveaxis(gathered[lay.window], 0, 1)      # (B, d, width)
            h = h.reshape(batch, lay.n_out)
        return ops.softmax_xent(h, y, force=self.kernel_mode)

    # ------------------------------------------------------- sharded layout

    @property
    def n_devices(self) -> int:
        return self.program.n_devices

    def param_spec(self) -> Params:
        """PartitionSpec pytree of the stacked sharded params layout."""
        return {"layers": [{"w": P(self.axis), "b": P(self.axis)}
                           for _ in range(self.program.l)]}

    def shard_params(self, params: Params) -> Params:
        """Full layout -> stacked residency layout.

        For layer i, device j's slot is column chunk ``owner_chunk[j]`` of
        (W_i, b_i) if j is in the layer's window, zeros otherwise — the
        memory image the program's residency annotations account for.
        Traceable (static slices), so it can run inside a jitted step to
        realise the "sliced once at step start" contract."""
        self._check_params(params, layout="full")
        n = self.n_devices
        layers = []
        for lay in self._layout:
            lp = params["layers"][lay.layer - 1]
            w, b = lp["w"], lp["b"]
            in_window = np.zeros(n, dtype=bool)
            in_window[lay.window] = True
            sw, sb = [], []
            for j in range(n):
                if in_window[j]:
                    c = int(lay.owner_chunk[j])
                    sw.append(w[:, c * lay.width:(c + 1) * lay.width])
                    sb.append(b[c * lay.width:(c + 1) * lay.width])
                else:
                    sw.append(jnp.zeros_like(w[:, :lay.width]))
                    sb.append(jnp.zeros_like(b[:lay.width]))
            layers.append({"w": jnp.stack(sw), "b": jnp.stack(sb)})
        return {"layers": layers}

    def gather_params(self, sparams: Params) -> Params:
        """Stacked residency layout -> full layout (chunk j of layer i
        comes from device window[j]'s slot).  The only place the full
        matrices are reassembled — used for eval/checkpoint interop, never
        inside the sharded loss."""
        self._check_params(sparams, layout="sharded")
        layers = []
        for lay in self._layout:
            sp = sparams["layers"][lay.layer - 1]
            w = jnp.concatenate([sp["w"][d] for d in lay.window], axis=1)
            b = jnp.concatenate([sp["b"][d] for d in lay.window], axis=0)
            layers.append({"w": w, "b": b})
        return {"layers": layers}

    # ------------------------------------------------------------------ api

    def loss_fn(self, params: Params, batch: Params) -> jax.Array:
        """Mean softmax cross-entropy of the program on ``batch``.

        ``params`` must be in the executor's residency layout: full
        (replicated mode) or stacked chunks from ``shard_params``
        (sharded mode)."""
        self._check_params(params, layout="full" if
                           self.residency == "replicated" else "sharded")
        return self._sharded(params, batch["x"], batch["y"])

    def _check_params(self, params: Params, layout: str = "full") -> None:
        sizes = self.program.layer_sizes
        layers = params["layers"]
        if len(layers) != self.program.l:
            raise ValueError(
                f"program has {self.program.l} layers, params have "
                f"{len(layers)}")
        for i, (lp, lay) in enumerate(zip(layers, self._layout)):
            if layout == "full":
                want = (sizes[i], sizes[i + 1])
            else:
                want = (self.n_devices, sizes[i], lay.width)
            if tuple(lp["w"].shape) != want:
                raise ValueError(
                    f"layer {i + 1}: weight shape {tuple(lp['w'].shape)} "
                    f"!= {layout}-layout shape {want}")


def build_train_step(
    program: PeriodProgram,
    mesh: Mesh,
    optimizer: Optimizer,
    kernel_mode: str | None = None,
) -> tuple[Callable, ProgramExecutor]:
    """A jitted ``step(params, opt_state, batch, i)`` whose loss is the
    compiled program executed under shard_map.  Drop-in for the plain
    single-device step of examples/train_fcnn_onoc.py.

    .. deprecated:: ISSUE 8 — use the façade:
       ``repro.exec.compile(...)`` / ``Executable.from_program(...)``
       and ``Executable.train_step(optimizer)``.  Kept as a thin
       replicated-residency shim."""
    from repro.deprecation import warn_deprecated
    warn_deprecated(
        "exec.runtime.build_train_step",
        "build_train_step is deprecated; use repro.exec.compile(...) "
        "or Executable.from_program(...).train_step(optimizer)")
    ex = ProgramExecutor(program, mesh, kernel_mode=kernel_mode)

    @jax.jit
    def step(params, opt_state, batch, i):
        loss, grads = jax.value_and_grad(ex.loss_fn)(params, batch)
        params, opt_state = optimizer.update(grads, opt_state, params, i)
        return params, opt_state, loss

    return step, ex
