"""Period-schedule execution engine.

The paper's fine-grained model assigns every one of the 2l periods of an
FCNN training epoch its own optimal core count, with a mapping strategy
(FM/RRM/ORRM) deciding how the active window moves between periods.  Until
this package existed the repo only *priced* those schedules
(``core.simulator``); here they become executable:

  * ``exec.program``  — the schedule compiler: lowers a planner plan plus a
    ``core.allocation.Mapping`` into a static, serializable per-period
    instruction program (RUN / SEND / RECV / FREE, alpa-style) whose cost
    annotations are cross-checkable against ``core.simulator.simulate_epoch``.
  * ``exec.runtime``  — the executor: interprets the program under
    ``jax.shard_map`` on a device mesh, driving the fused Pallas kernels
    (``kernels.ops``) as the per-shard math, in one of two residency
    modes: ``"sharded"`` (each device holds only its column chunks; FREE
    releases them at the Eq.-11 mirror periods) or ``"replicated"`` (the
    PR-6 full-model oracle).
  * ``exec.residency`` — per-device live-bytes accounting over the
    program's schema-v2 residency annotations.
  * ``exec.validate``  — static verifier: schedule invariants, the
    residency byte ledger, and the cost contract vs the simulator.
  * ``exec.analysis``  — the per-device static analyzer (ISSUE 9):
    expands the SPMD program into one stream per device and checks
    happens-before (deadlocks, endpoints), chunk-granular memory safety
    and shape/dtype abstract interpretation; runs at compile time
    (``compile(analyze=...)``) and after every replan.
  * ``exec.api``      — the façade: ``repro.exec.compile(workload, cfg,
    mesh, strategy=..., residency=...) -> Executable`` with
    ``.train_step()`` / ``.loss_fn()`` / ``.program`` / ``.degrade()``,
    replacing the scattered compile/validate/executor/step-builder chain
    (the old entry points below remain as deprecation shims).

See exec/README.md for the API and dispatch rules.
"""

from repro.exec.analysis import (  # noqa: F401
    AnalysisReport,
    ProgramAnalysisError,
    analyze_program,
    corruption_corpus,
    expand_program,
)
from repro.exec.api import (  # noqa: F401
    Executable,
    compile,
)
from repro.exec.program import (  # noqa: F401
    Instruction,
    Opcode,
    PeriodProgram,
    compile_fcnn_program,
    compile_program,
)
from repro.exec.residency import (  # noqa: F401
    ResidencyTracker,
)
from repro.exec.runtime import (  # noqa: F401
    ProgramExecutor,
    build_train_step,
)
from repro.exec.validate import (  # noqa: F401
    ProgramValidationError,
    validate_program,
)

__all__ = [
    "compile",
    "Executable",
    "AnalysisReport",
    "ProgramAnalysisError",
    "analyze_program",
    "corruption_corpus",
    "expand_program",
    "Opcode",
    "Instruction",
    "PeriodProgram",
    "ResidencyTracker",
    "compile_program",
    "compile_fcnn_program",
    "ProgramExecutor",
    "build_train_step",
    "ProgramValidationError",
    "validate_program",
]
