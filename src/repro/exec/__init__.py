"""Period-schedule execution engine.

The paper's fine-grained model assigns every one of the 2l periods of an
FCNN training epoch its own optimal core count, with a mapping strategy
(FM/RRM/ORRM) deciding how the active window moves between periods.  Until
this package existed the repo only *priced* those schedules
(``core.simulator``); here they become executable:

  * ``exec.program``  — the schedule compiler: lowers a planner plan plus a
    ``core.allocation.Mapping`` into a static, serializable per-period
    instruction program (RUN / SEND / RECV / FREE, alpa-style) whose cost
    annotations are cross-checkable against ``core.simulator.simulate_epoch``.
  * ``exec.runtime``  — the executor: interprets the program under
    ``jax.shard_map`` on a device mesh, driving the fused Pallas kernels
    (``kernels.ops``) as the per-shard math.
"""

from repro.exec.program import (  # noqa: F401
    Instruction,
    Opcode,
    PeriodProgram,
    compile_fcnn_program,
    compile_program,
)
from repro.exec.runtime import (  # noqa: F401
    ProgramExecutor,
    build_train_step,
)
from repro.exec.validate import (  # noqa: F401
    ProgramValidationError,
    validate_program,
)

__all__ = [
    "Opcode",
    "Instruction",
    "PeriodProgram",
    "compile_program",
    "compile_fcnn_program",
    "ProgramExecutor",
    "build_train_step",
    "ProgramValidationError",
    "validate_program",
]
