from .sharding import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    logical_to_mesh,
    named_sharding,
    shard_constraint,
    tree_shardings,
)
