"""Logical-axis sharding rules (MaxText-style), consumed by every model.

Tensors are annotated with *logical* axis names; the mesh maps them to
physical axes.  The ONoC planner (core/planner.py) edits these rules to
realize its per-period parallelism degrees: a layer planned at degree 1
gets its "mlp"/"heads" axes mapped to None (replicated), a layer planned at
full degree keeps "model" (+ "data" for fused degrees).

Physical axes:
  "pod"    across pods (multi-pod mesh only)
  "data"   data parallel + FSDP (ZeRO-3 weight sharding)
  "model"  tensor parallel
"""

from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "AxisRules",
    "DEFAULT_RULES",
    "logical_to_mesh",
    "named_sharding",
    "replicate",
    "shard_constraint",
    "shard_stacked",
    "tree_shardings",
]

# logical name -> physical axis (or tuple of axes, or None)
_DEFAULT = {
    # activations
    "activation_batch": ("pod", "data"),
    "activation_length": None,
    "residual_length": None,  # inter-block residual stream (Megatron-SP
                              # shards this on "model" between blocks)
    "activation_embed": None,
    "activation_heads": "model",
    "activation_kv_heads": "model",
    "activation_mlp": "model",
    "activation_vocab": "model",
    "activation_exp": "model",
    # weights
    "embed": "data",          # FSDP axis of weight matrices
    "vocab": "model",
    "table_embed": "data",    # embedding table d_model axis (separable from
                              # "embed" so vocab-parallel embedding can
                              # unshard it without touching FSDP)
    "heads": "model",
    "kv_heads": "model",
    "q_per_kv": None,
    "head_dim": None,
    "mlp": "model",
    "experts": "model",       # expert parallelism
    "expert_mlp": None,
    "conv_kernel": None,
    "state": None,
    "layers": None,           # scan axis of stacked layer params
    # kv-cache
    "cache_batch": ("pod", "data"),
    "cache_length": None,
    "cache_kv_heads": "model",
    "cache_head_dim": None,
}


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """An immutable logical->physical mapping with functional overrides."""

    table: Mapping[str, Any] = dataclasses.field(
        default_factory=lambda: dict(_DEFAULT)
    )

    def override(self, **changes: Any) -> "AxisRules":
        t = dict(self.table)
        for k, v in changes.items():
            if k not in t:
                raise KeyError(f"unknown logical axis {k!r}")
            t[k] = v
        return AxisRules(table=t)

    def physical(self, logical: str | None, mesh: Mesh) -> Any:
        if logical is None:
            return None
        if logical not in self.table:
            raise KeyError(f"unknown logical axis {logical!r}")
        phys = self.table[logical]
        if phys is None:
            return None
        if isinstance(phys, str):
            return phys if phys in mesh.axis_names else None
        # tuple of axes — keep only those present on this mesh
        kept = tuple(a for a in phys if a in mesh.axis_names)
        return kept if kept else None


DEFAULT_RULES = AxisRules()

# Dynamically-scoped active rules: in-model shard_constraint calls resolve
# against these, so planners/experiments retarget every internal constraint
# without threading a rules object through model code.  Trace-time scoped:
# wrap the .lower()/jit call in ``use_rules``.
_ACTIVE_RULES: list[AxisRules] = [DEFAULT_RULES]


class use_rules:
    def __init__(self, rules: AxisRules):
        self.rules = rules

    def __enter__(self):
        _ACTIVE_RULES.append(self.rules)
        return self.rules

    def __exit__(self, *exc):
        _ACTIVE_RULES.pop()
        return False


def active_rules() -> AxisRules:
    return _ACTIVE_RULES[-1]


def logical_to_mesh(
    logical_axes: Sequence[str | None], mesh: Mesh, rules: AxisRules = DEFAULT_RULES
) -> P:
    """Translate a tuple of logical axis names into a PartitionSpec."""
    return P(*(rules.physical(a, mesh) for a in logical_axes))


def named_sharding(
    logical_axes: Sequence[str | None], mesh: Mesh, rules: AxisRules = DEFAULT_RULES
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_mesh(logical_axes, mesh, rules))


def shard_constraint(
    x: jax.Array,
    logical_axes: Sequence[str | None],
    mesh: Mesh | None = None,
    rules: AxisRules | None = None,
) -> jax.Array:
    """with_sharding_constraint by logical names; no-op off-mesh (CPU tests).

    ``rules`` defaults to the dynamically-scoped active rules (use_rules)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = rules or active_rules()
    spec = logical_to_mesh(logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def replicate(tree: Any, mesh: Mesh) -> Any:
    """device_put a pytree fully replicated across ``mesh``.

    The period-program executor's *replicated*-residency placement
    (exec/runtime.py oracle path): every device holds the full
    params/batch and slices its per-period chunk on-device.  The
    weight-sharded residency path instead stacks per-device chunks and
    splits them over the ring axis (``shard_stacked``), holding ~1/d of
    the model per device."""
    sh = NamedSharding(mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, sh), tree)


def shard_stacked(tree: Any, mesh: Mesh, axis: str | None = None) -> Any:
    """device_put a pytree of *stacked* per-device leaves — shape
    ``(n_devices, ...)`` — split over ``axis`` (default: the mesh's only
    axis), leaving scalars and non-stacked leaves replicated.

    This is the resident layout of the weight-sharded period-program
    executor (exec/runtime.py): leaf ``[j]`` is device j's column chunk,
    so the device materializes exactly its ``param_bytes`` of each layer
    (exec.residency accounting)."""
    axis = axis or mesh.axis_names[0]
    n = _axis_size(mesh, axis)

    def put(x):
        stacked = getattr(x, "ndim", 0) >= 1 and x.shape[0] == n
        spec = P(axis) if stacked else P()
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree)


def _current_mesh() -> Mesh | None:
    env = jax._src.mesh.thread_resources.env  # the `with mesh:` context
    m = env.physical_mesh
    return None if m.empty else m


def tree_shardings(
    tree_axes: Any, mesh: Mesh, rules: AxisRules = DEFAULT_RULES
) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of NamedShardings.

    Leaves are tuples/lists of logical names (or None for fully replicated).
    """

    def leaf(ax):
        if ax is None:
            return NamedSharding(mesh, P())
        return named_sharding(tuple(ax), mesh, rules)

    return jax.tree.map(
        leaf, tree_axes, is_leaf=lambda x: x is None or isinstance(x, (tuple, list))
    )


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def resolve_spec(shape: tuple[int, ...], logical_axes, mesh: Mesh,
                 rules: AxisRules = DEFAULT_RULES) -> P:
    """Shape-aware PartitionSpec: demote any mesh axis that does not divide
    its dimension (e.g. 8 GQA kv-heads over a 16-way "model" axis, or 60
    experts over 16) to the longest dividing prefix, else replicate.

    This is the production fallback: the plan stays valid on every mesh and
    the roofline report shows where demotion cost capacity (a hillclimb
    lever, see EXPERIMENTS.md §Perf)."""
    if logical_axes is None:
        return P()
    spec = []
    for dim, ax in zip(shape, tuple(logical_axes)):
        phys = rules.physical(ax, mesh)
        if phys is None:
            spec.append(None)
            continue
        names = (phys,) if isinstance(phys, str) else tuple(phys)
        if dim % _axis_size(mesh, names) == 0:
            spec.append(phys)
            continue
        kept = []
        cur = 1
        for a in names:
            if dim % (cur * mesh.shape[a]) == 0:
                kept.append(a)
                cur *= mesh.shape[a]
            else:
                break
        spec.append(tuple(kept) if kept else None)
    # pad spec for trailing unlisted dims
    return P(*spec)


def shape_aware_shardings(
    spec_tree: Any, axes_tree: Any, mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> Any:
    """Like tree_shardings, but consults leaf shapes (ShapeDtypeStructs or
    arrays) and demotes non-dividing axes — every returned sharding is
    valid for jit in_shardings on this mesh.

    The two trees must have the same structure; axes leaves are tuples of
    logical names or None (fully replicated)."""
    spec_leaves, treedef = jax.tree_util.tree_flatten(spec_tree)
    is_axes_leaf = lambda x: x is None or (  # noqa: E731
        isinstance(x, tuple)
        and all(i is None or isinstance(i, str) for i in x))
    axes_leaves, _ = jax.tree_util.tree_flatten(axes_tree,
                                                is_leaf=is_axes_leaf)
    if len(spec_leaves) != len(axes_leaves):
        raise ValueError(
            f"structure mismatch: {len(spec_leaves)} arrays vs "
            f"{len(axes_leaves)} axes leaves")
    shardings = [
        NamedSharding(mesh, resolve_spec(tuple(s.shape), a, mesh, rules))
        for s, a in zip(spec_leaves, axes_leaves)
    ]
    return jax.tree_util.tree_unflatten(treedef, shardings)
