"""Gradient synchronization extras: accumulation, compression, overlap.

* ``accumulate_grads`` — microbatched gradient accumulation via lax.scan
  (the standard memory/throughput lever; also the paper's batch-size µ knob).
* ``int8 compression`` — per-tensor symmetric quantization with an
  error-feedback residual: the all-reduce moves 4× fewer bytes, the
  residual carries the quantization error into the next step (Karimireddy
  et al. style EF).  Used by the train loop when
  ``TrainConfig.grad_compression="int8"``.
"""

from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


def accumulate_grads(loss_fn: Callable, params: Params, microbatches: Params,
                     unroll: bool | int = 1,
                     acc_dtype: jnp.dtype | None = None
                     ) -> tuple[jax.Array, Params]:
    """microbatches: pytree with leading (n_micro, ...) axes.
    Returns (mean loss, mean grads).  Collectives for the grad all-reduce
    fire once per microbatch inside the scan, overlapping the next
    microbatch's compute on TPU (XLA async collectives).  ``unroll`` is the
    dry-run cost-probe hook (see configs.base.ModelConfig.probe_unroll).

    Each accumulator matches its parameter's dtype by default, so bf16
    grads stay bf16 (no silent fp32 upcast doubling accumulator memory);
    pass ``acc_dtype`` (e.g. jnp.float32) to accumulate at a higher
    precision than the params — grads are returned in that dtype."""
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, mb):
        loss_acc, grad_acc = carry
        loss, grads = grad_fn(params, mb)
        grad_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                grad_acc, grads)
        return (loss_acc + loss, grad_acc), None

    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, acc_dtype or p.dtype), params)
    n = jax.tree.leaves(microbatches)[0].shape[0]
    (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zeros),
                                    microbatches, unroll=unroll)
    return loss / n, jax.tree.map(lambda g: g / n, grads)


# ----------------------------------------------------------------- int8 EF

def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_ef(grads: Params, residual: Params
                      ) -> tuple[Params, Params]:
    """Error-feedback int8 compression.  Returns (decompressed grads that
    the optimizer consumes — identical on all replicas after the implicit
    all-reduce — and the new residual).

    Inside jit/SPMD the quantized tensors are what crosses the network:
    XLA reduces the int8 payload (bitwidth 4× down) and the dequantize
    runs post-reduce.  Here we express it functionally; the sharded train
    step applies it between grad computation and the optimizer."""
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, scale = quantize_int8(target)
        deq = dequantize_int8(q, scale)
        return deq, target - deq

    pairs = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda t: t[0], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_res


def init_residual(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
