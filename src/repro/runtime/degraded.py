"""Degraded-mode training: fault injection -> replan -> checkpoint-resume.

``DegradedModeRunner`` closes the loop the rest of the fault layer only
prices or detects:

  1. every training step walks the compiled ``PeriodProgram``'s
     instruction list and lets the ``FaultInjector`` fire scheduled faults
     at instruction boundaries;
  2. transient RUN faults propagate to ``TrainingSupervisor``'s bounded
     retry-with-backoff loop (and, past ``max_retries``, its
     restart-from-checkpoint fallback);
  3. a kernel failure on the fused path degrades the executor to the jnp
     reference path (``ProgramExecutor.degrade``) and rebuilds the jitted
     step — recorded as a ``kernel_fallback`` in the ``FaultReport``;
  4. a ``DeviceLossFault`` is fatal to the current mesh: the runner asks
     ``ElasticPlanner.replan_program`` for the Lemma-1 plan on the
     survivors, re-validates and recompiles the period program for the
     shrunken ring, rebuilds the mesh + executor, and re-enters the
     supervisor — which restores the latest complete checkpoint
     (including ``Batcher`` state, so no sample is skipped or repeated)
     and resumes training where it left off.

Because the executor's numerics are device-count invariant (each weight
chunk is computed by exactly one selected device; losses/grads match the
single-device path to fp tolerance), the post-replan loss trajectory
coincides with a from-scratch run on the small mesh — pinned by
tests/test_fault_recovery.py.

The runner is deliberately CPU-friendly: with ``make_test_mesh`` it
exercises the full loss->replan->resume path on forced host devices (the
CI ``fault-smoke`` job runs exactly that via examples/elastic_restart.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.core.allocation import MappingStrategy
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.exec.runtime import ProgramExecutor
from repro.exec.validate import validate_program
from repro.launch.mesh import make_test_mesh
from repro.optim.optimizers import Optimizer
from repro.parallel.sharding import replicate
from repro.runtime.elastic import ElasticPlanner
from repro.runtime.fault_tolerance import TrainingSupervisor
from repro.runtime.faults import (
    DeviceLossFault,
    FaultError,
    FaultInjector,
    FaultReport,
    FaultSchedule,
)

__all__ = ["DegradedModeRunner"]


@dataclasses.dataclass
class DegradedModeRunner:
    """Drives training through TrainingSupervisor under a FaultSchedule,
    replanning + recompiling + resuming-from-checkpoint on device loss.

    ``workload.m``-independent: the paper config's ``m`` is re-derived from
    the live device count at every (re)plan, so Lemma 1 always answers for
    the ring that actually exists.

    ``residency`` selects the executor path per ISSUE 8: ``"sharded"``
    runs the weight-sharded executor (params sliced once at step start
    into per-device chunks, ~1/d resident bytes), with the *canonical*
    state kept in the full layout so checkpoints restore across replans
    whose survivor rings have different chunk geometry; ``"replicated"``
    is the PR-6 oracle.  Both paths produce bit-identical losses, so the
    post-replan-equals-from-scratch pin holds in either mode.
    """

    workload: FCNNWorkload
    base_cfg: ONoCConfig
    schedule: FaultSchedule
    checkpointer: Checkpointer
    optimizer: Optimizer
    n_devices: int
    strategy: MappingStrategy = MappingStrategy.ORRM
    kernel_mode: str | None = None
    residency: str = "replicated"
    backend: Any = None
    analyze: str = "full"               # exec.analysis level per rebuild
    checkpoint_every: int = 2
    max_retries: int = 3
    backoff_s: float = 0.01
    mesh_factory: Callable[[int], Any] | None = None
    report: FaultReport = dataclasses.field(default_factory=FaultReport)

    def __post_init__(self) -> None:
        self.injector = FaultInjector(self.schedule, report=self.report)
        self.planner = ElasticPlanner(self.workload, self.base_cfg,
                                      strategy=self.strategy)
        self.losses: dict[int, float] = {}   # step -> last observed loss
        self.program = None
        self.executable = None
        self.executor: ProgramExecutor | None = None
        self._step_jit = None
        self._mesh = None

    # ---------------------------------------------------------------- build

    def _make_mesh(self, n_devices: int):
        if self.mesh_factory is not None:
            return self.mesh_factory(n_devices)
        return make_test_mesh(n_devices)

    def _build(self, n_devices: int) -> None:
        """(Re)plan, recompile, re-validate and rebuild mesh + executor +
        jitted step for ``n_devices`` survivors."""
        cfg, plan, program = self.planner.replan_program(
            n_devices, backend=self.backend)
        # compile_program already validated; re-assert explicitly so the
        # replan path cannot lose the check if compile defaults change,
        # and re-run the per-device static analyzer — a replanned program
        # for a shrunken ring is exactly where a schedule bug would
        # surface first (exec/analysis; ``analyze="off"`` skips it).
        validate_program(program, self.workload, cfg, backend=self.backend,
                         analyze=None if self.analyze == "off"
                         else self.analyze)
        self.program = program
        self._mesh = self._make_mesh(n_devices)
        # The façade re-derives residency for the survivor ring: the
        # recompiled schema-v2 program carries the survivors' chunk
        # geometry + param FREEs, and the executor's tracker accounts it.
        from repro.exec.api import Executable
        exe = Executable.from_program(
            program, self._mesh, residency=self.residency,
            kernel_mode=self.kernel_mode, workload=self.workload, cfg=cfg,
            plan=plan, backend=self.backend)
        self.executable = exe
        self.executor = exe.executor
        self._step_jit = self._fresh_step()

    def _fresh_step(self):
        ex, opt = self.executor, self.optimizer

        if ex.residency == "sharded":
            # Canonical state stays in the full layout so checkpoints are
            # portable across replans (each survivor ring has different
            # chunk geometry).  Params are sliced once at step start into
            # the stacked residency layout and never re-gathered whole
            # inside the program; only the grads come back full for the
            # layout-independent optimizer update.
            @jax.jit
            def step(params, opt_state, batch, i):
                sp = ex.shard_params(params)
                loss, sgrads = jax.value_and_grad(ex.loss_fn)(sp, batch)
                grads = ex.gather_params(sgrads)
                params, opt_state = opt.update(grads, opt_state, params, i)
                return params, opt_state, loss

            return step

        @jax.jit
        def step(params, opt_state, batch, i):
            loss, grads = jax.value_and_grad(ex.loss_fn)(params, batch)
            params, opt_state = opt.update(grads, opt_state, params, i)
            return params, opt_state, loss

        return step

    # ----------------------------------------------------------------- step

    def _step_fn(self, state: dict, batch: dict) -> tuple[dict, dict]:
        step = int(state["step"])
        for instr in self.program.instructions:
            self.injector.instruction_boundary(step, instr)
        t0 = time.monotonic()
        try:
            params, opt_state, loss = self._step_jit(
                state["params"], state["opt_state"], batch, state["step"])
        except FaultError:
            raise
        except Exception:
            # kernel failure on the fused path: degrade to the reference
            # path once, rebuild the jitted step, retry.  Already-degraded
            # executors re-raise (a ref-path failure is a real bug).
            if self.executor.kernel_mode == "ref":
                raise
            self.executor.degrade("ref")
            self.report.kernel_fallbacks += 1
            self._step_jit = self._fresh_step()
            params, opt_state, loss = self._step_jit(
                state["params"], state["opt_state"], batch, state["step"])
        self.injector.observe_step(step, time.monotonic() - t0)
        loss_f = float(loss)
        self.losses[step] = loss_f
        state = {"params": params, "opt_state": opt_state,
                 "step": state["step"] + 1}
        return state, {"loss": loss_f}

    # ------------------------------------------------------------------ run

    def run(self, params: Any, opt_state: Any, batches: Any,
            n_steps: int) -> tuple[dict, list[dict], FaultReport]:
        """Train ``n_steps`` under the fault schedule.  Returns the final
        state dict ``{"params", "opt_state", "step"}``, the supervisor's
        metric history, and the structured FaultReport."""
        n = self.n_devices
        state0 = {"params": params, "opt_state": opt_state,
                  "step": jnp.asarray(0, jnp.int32)}
        data_state0 = batches.state() if hasattr(batches, "state") else None
        history: list[dict] = []
        state = state0
        while True:
            self._build(n)
            state = replicate(state, self._mesh)
            shardings = jax.tree.map(
                lambda _: jax.sharding.NamedSharding(
                    self._mesh, jax.sharding.PartitionSpec()), state)
            supervisor = TrainingSupervisor(
                checkpointer=self.checkpointer,
                checkpoint_every=self.checkpoint_every,
                max_retries=self.max_retries,
                backoff_s=self.backoff_s,
                fatal=(DeviceLossFault,),
            )
            try:
                state, hist = supervisor.run(
                    state, self._step_fn, batches, n_steps,
                    start_step=0, restore_shardings=shardings)
                history.extend(hist)
                return state, history, self.report
            except DeviceLossFault as e:
                self.checkpointer.wait()   # flush any in-flight async save
                lost = [d for d in e.devices if d < n]
                survivors = n - len(lost)
                if survivors < 1:
                    raise
                last = supervisor.latest()
                self.report.replans.append({
                    "step": e.step, "period": e.period, "lost": lost,
                    "from_devices": n, "to_devices": survivors,
                    "resume_checkpoint": last,
                })
                self.report.resumed_from.append(
                    last if last is not None else -1)
                if last is None:
                    # no checkpoint yet: genuine from-scratch restart on
                    # the survivors — rewind state and the data pipeline.
                    state = state0
                    if data_state0 is not None:
                        batches.restore(data_state0)
                n = survivors
