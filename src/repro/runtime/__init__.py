from .fault_tolerance import TrainingSupervisor, StragglerMonitor  # noqa: F401
from .elastic import ElasticPlanner  # noqa: F401
from .faults import (  # noqa: F401
    DeviceLossFault,
    EpochFaults,
    FaultError,
    FaultEvent,
    FaultInjector,
    FaultKind,
    FaultReport,
    FaultSchedule,
    TransientRunFault,
    expected_epoch_time,
)
from .degraded import DegradedModeRunner  # noqa: F401
