from .fault_tolerance import TrainingSupervisor, StragglerMonitor  # noqa: F401
from .elastic import ElasticPlanner  # noqa: F401
