"""Deterministic fault injection for the period-schedule execution engine.

Photonic substrates make degradation the *expected* operating regime —
thermal drift detunes ring resonators (wavelength loss), device variation
degrades links, and cores fail like anywhere else — so the repro carries a
first-class fault model instead of a happy-path executor.  Everything here
is seeded and replayable: the same ``FaultSchedule`` produces the same
faults at the same (step, period) boundaries every run.

Fault taxonomy (``FaultKind``):

  DEVICE_LOSS          a core leaves the ring permanently, mid-epoch.  The
                       recovery path (runtime/degraded.py) re-derives the
                       Lemma-1 plan on the survivors, recompiles the period
                       program, and resumes from the latest checkpoint.
  TRANSIENT_RUN        one period's RUN fails but the device survives
                       (SEU, kernel launch failure).  Cleared by bounded
                       retry with backoff (TrainingSupervisor).
  STRAGGLER            a period runs ``magnitude``× slow (thermal
                       throttling, contended link).  Observed by
                       StragglerMonitor / timeout hooks; inflates compute
                       in the pricing model.
  WAVELENGTH_DEGRADE   a fraction of the WDM comb is lost (ONoC): fewer
                       usable wavelengths => more TDM slots per transition.
  LINK_DEGRADE         a fraction of link capacity is lost: transition
                       drain times inflate by 1/(1-magnitude).

Injection points:

  * ``core.simulator.simulate_epoch(..., faults=EpochFaults(...))`` —
    fault-aware epoch *pricing* on both backends; see
    ``expected_epoch_time`` for the full failure-model price (degraded
    epoch + device-loss re-transition + replanned remainder).
  * ``FaultInjector.instruction_boundary`` — runtime injection: the
    degraded-mode runner walks the compiled program's instruction list
    each step and lets scheduled faults fire at instruction boundaries
    (raising ``TransientRunFault`` / ``DeviceLossFault``).

Every fired fault and every recovery action (retry, kernel fallback,
replan, timeout) is recorded in a structured ``FaultReport`` that
``benchmarks/run.py --json`` serializes.
"""

from __future__ import annotations

import dataclasses
import enum
import math
import time
from typing import Callable

import numpy as np

from repro.core.onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    optimal_cores,
)
from repro.core.simulator import TransitionTraffic

__all__ = [
    "FaultKind",
    "FaultEvent",
    "FaultSchedule",
    "FaultError",
    "TransientRunFault",
    "DeviceLossFault",
    "KernelFault",
    "FaultReport",
    "FaultInjector",
    "EpochFaults",
    "FaultPricing",
    "expected_epoch_time",
]


class FaultKind(str, enum.Enum):
    DEVICE_LOSS = "device_loss"
    TRANSIENT_RUN = "transient_run"
    STRAGGLER = "straggler"
    WAVELENGTH_DEGRADE = "wavelength_degrade"
    LINK_DEGRADE = "link_degrade"


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``step``   training step (= one epoch of the paper's model) at which
               the fault fires.
    ``period`` instruction boundary within the step: the fault fires when
               the runner reaches period ``period``'s first instruction
               (0 = the very first boundary of the step).
    ``device`` target core (DEVICE_LOSS / TRANSIENT_RUN); None = unpinned.
    ``magnitude``  STRAGGLER: slowdown factor (>= 1);
                   *_DEGRADE: fraction of capacity lost in [0, 1).
    ``count``  how many times the fault fires before clearing — a
               TRANSIENT_RUN with count=2 fails two attempts and succeeds
               on the third (exercising bounded retry).
    """

    kind: FaultKind
    step: int
    period: int = 0
    device: int | None = None
    magnitude: float = 1.0
    count: int = 1

    def to_dict(self) -> dict:
        return {**dataclasses.asdict(self), "kind": self.kind.value}


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A deterministic, replayable set of fault events."""

    events: tuple[FaultEvent, ...] = ()
    seed: int | None = None

    @classmethod
    def sample(
        cls,
        seed: int,
        n_steps: int,
        n_devices: int,
        n_periods: int,
        rates: dict[FaultKind, float] | None = None,
    ) -> "FaultSchedule":
        """Bernoulli-per-step sampling of each fault kind at the given
        per-step rates — same seed, same schedule, every run."""
        rng = np.random.default_rng(seed)
        rates = rates or {}
        events: list[FaultEvent] = []
        for step in range(n_steps):
            for kind, rate in rates.items():
                if rng.random() >= rate:
                    continue
                events.append(FaultEvent(
                    kind=FaultKind(kind),
                    step=step,
                    period=int(rng.integers(1, max(n_periods, 1) + 1)),
                    device=int(rng.integers(n_devices)),
                    magnitude=(float(1.0 + 3.0 * rng.random())
                               if kind == FaultKind.STRAGGLER
                               else float(0.25 + 0.5 * rng.random())),
                ))
        return cls(events=tuple(events), seed=seed)

    @classmethod
    def seeded_device_loss(
        cls,
        seed: int,
        n_steps: int,
        n_devices: int,
        n_periods: int,
        n_lost: int = 1,
    ) -> "FaultSchedule":
        """One seeded mid-run, mid-epoch device-loss burst: the step is
        drawn from the middle of the run (so a checkpoint exists and steps
        remain), the period from within the epoch, the lost cores without
        replacement."""
        rng = np.random.default_rng(seed)
        lo, hi = max(1, n_steps // 3), max(2, 2 * n_steps // 3)
        step = int(rng.integers(lo, hi + 1))
        period = int(rng.integers(1, max(n_periods, 1) + 1))
        lost = rng.choice(n_devices, size=n_lost, replace=False)
        events = tuple(
            FaultEvent(kind=FaultKind.DEVICE_LOSS, step=step, period=period,
                       device=int(d))
            for d in sorted(int(d) for d in lost)
        )
        return cls(events=events, seed=seed)

    def at(self, step: int, period: int | None = None) -> tuple[FaultEvent, ...]:
        """Events scheduled for ``step`` (optionally at one period)."""
        return tuple(
            e for e in self.events
            if e.step == step and (period is None or e.period == period)
        )

    def device_losses(self, step: int | None = None) -> tuple[FaultEvent, ...]:
        return tuple(
            e for e in self.events
            if e.kind is FaultKind.DEVICE_LOSS
            and (step is None or e.step == step)
        )

    def transient_runs(self, step: int | None = None) -> tuple[FaultEvent, ...]:
        return tuple(
            e for e in self.events
            if e.kind is FaultKind.TRANSIENT_RUN
            and (step is None or e.step == step)
        )

    def to_dicts(self) -> list[dict]:
        return [e.to_dict() for e in self.events]


# --------------------------------------------------------------------------
# runtime injection
# --------------------------------------------------------------------------


class FaultError(RuntimeError):
    """Base class of all injected faults."""


class TransientRunFault(FaultError):
    """A RUN failed but the device survives — retryable."""

    def __init__(self, step: int, period: int, device: int | None):
        super().__init__(
            f"injected transient RUN failure at step {step}, period "
            f"{period} (device {device})")
        self.step, self.period, self.device = step, period, device


class DeviceLossFault(FaultError):
    """A device left the ring — not retryable, triggers replanning."""

    def __init__(self, step: int, period: int, devices: tuple[int, ...]):
        super().__init__(
            f"injected device loss at step {step}, period {period}: "
            f"devices {list(devices)} left the ring")
        self.step, self.period, self.devices = step, period, devices


class KernelFault(FaultError):
    """A kernel path failed; the executor degraded to the reference path."""


@dataclasses.dataclass
class FaultReport:
    """Structured record of injected faults and recovery actions — the
    machine-readable artifact ``benchmarks/run.py --json`` stores."""

    fired: list[dict] = dataclasses.field(default_factory=list)
    retries: int = 0
    straggles: int = 0
    timeouts: int = 0
    kernel_fallbacks: int = 0
    replans: list[dict] = dataclasses.field(default_factory=list)
    resumed_from: list[int] = dataclasses.field(default_factory=list)

    def record(self, event: FaultEvent, **extra) -> None:
        self.fired.append({**event.to_dict(), **extra})

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class FaultInjector:
    """Fires a FaultSchedule at instruction boundaries and records
    everything in a FaultReport.

    ``sleep_scale`` scales STRAGGLER magnitudes into real wall-clock sleep
    seconds (0 = record-only, the CI-safe default).  ``timeout_s`` +
    ``on_timeout`` are the per-step timeout hook: ``observe_step`` compares
    each step's wall time against the budget and fires the hook on
    overrun (on a real cluster the hook would re-dispatch the shard).
    """

    schedule: FaultSchedule
    report: FaultReport = dataclasses.field(default_factory=FaultReport)
    sleep_scale: float = 0.0
    timeout_s: float | None = None
    on_timeout: Callable[[int, float], None] | None = None
    _fired_counts: dict[int, int] = dataclasses.field(default_factory=dict)

    def _fires(self, event: FaultEvent) -> bool:
        n = self._fired_counts.get(id(event), 0)
        if n >= event.count:
            return False
        self._fired_counts[id(event)] = n + 1
        return True

    def instruction_boundary(self, step: int, instr) -> None:
        """Called by the runner before each instruction of each step; may
        raise TransientRunFault / DeviceLossFault.  Period-0 events fire at
        the first boundary of the step (period-1 RUN)."""
        first = instr.period == 1 and getattr(instr.opcode, "value",
                                              instr.opcode) == "run"
        hits = [e for e in self.schedule.at(step)
                if e.period == instr.period or (e.period == 0 and first)]
        losses: list[FaultEvent] = []
        for e in hits:
            if e.kind is FaultKind.DEVICE_LOSS:
                if self._fires(e):
                    losses.append(e)
            elif e.kind is FaultKind.TRANSIENT_RUN:
                if self._fires(e):
                    self.report.retries += 1
                    self.report.record(e)
                    raise TransientRunFault(step, instr.period, e.device)
            elif e.kind is FaultKind.STRAGGLER:
                if self._fires(e):
                    self.report.straggles += 1
                    self.report.record(e)
                    if self.sleep_scale > 0:
                        time.sleep(e.magnitude * self.sleep_scale)
            else:  # degradation faults are pricing-level; record once
                if self._fires(e):
                    self.report.record(e)
        if losses:
            devs = tuple(sorted({e.device for e in losses
                                 if e.device is not None}))
            for e in losses:
                self.report.record(e)
            raise DeviceLossFault(step, instr.period, devs)

    def observe_step(self, step: int, duration_s: float) -> None:
        if self.timeout_s is not None and duration_s > self.timeout_s:
            self.report.timeouts += 1
            if self.on_timeout is not None:
                self.on_timeout(step, duration_s)


# --------------------------------------------------------------------------
# simulator-side pricing
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EpochFaults:
    """The simulator's view of one step's non-fatal faults — the object
    ``core.simulator.simulate_epoch`` accepts as ``faults=``.

    ``wavelength_loss``  fraction of the WDM comb lost (ONoC: lambda_max
                         shrinks, so each transition needs more TDM slots).
    ``link_degrade``     period -> fraction of link capacity lost (0 = all
                         periods); transition time inflates by 1/(1-f) on
                         either backend.
    ``straggle``         period -> compute slowdown factor >= 1 (0 = all).
    """

    wavelength_loss: float = 0.0
    link_degrade: dict[int, float] = dataclasses.field(default_factory=dict)
    straggle: dict[int, float] = dataclasses.field(default_factory=dict)

    @classmethod
    def from_schedule(cls, schedule: FaultSchedule,
                      step: int | None = None) -> "EpochFaults":
        wl = 0.0
        link: dict[int, float] = {}
        strag: dict[int, float] = {}
        for e in schedule.events:
            if step is not None and e.step != step:
                continue
            if e.kind is FaultKind.WAVELENGTH_DEGRADE:
                wl = 1.0 - (1.0 - wl) * (1.0 - e.magnitude)
            elif e.kind is FaultKind.LINK_DEGRADE:
                prev = link.get(e.period, 0.0)
                link[e.period] = 1.0 - (1.0 - prev) * (1.0 - e.magnitude)
            elif e.kind is FaultKind.STRAGGLER:
                strag[e.period] = max(strag.get(e.period, 1.0), e.magnitude)
        return cls(wavelength_loss=wl, link_degrade=link, straggle=strag)

    # --- hooks consumed by core.simulator.simulate_epoch ---

    def apply_config(self, cfg: ONoCConfig) -> ONoCConfig:
        if self.wavelength_loss <= 0.0:
            return cfg
        lam = max(1, int(math.floor(
            cfg.lambda_max * (1.0 - self.wavelength_loss))))
        return dataclasses.replace(cfg, lambda_max=lam)

    def compute_scale(self, period: int) -> float:
        return max(self.straggle.get(period, 1.0), self.straggle.get(0, 1.0))

    def apply_transition(self, tr: TransitionTraffic,
                         period: int) -> TransitionTraffic:
        lost = max(self.link_degrade.get(period, 0.0),
                   self.link_degrade.get(0, 0.0))
        if lost <= 0.0:
            return tr
        cap = max(1.0 - lost, 1e-9)
        return dataclasses.replace(tr, comm_s=tr.comm_s / cap)


@dataclasses.dataclass(frozen=True)
class FaultPricing:
    """Epoch price under a failure model (see ``expected_epoch_time``).

    ``strategy`` is the normalized mapping-strategy value every component
    of the price was simulated under — retry/prefix pricing only matches
    a ``simulate_epoch`` cross-check run under the *same* strategy (note
    the defaults differ: ``expected_epoch_time`` prices ORRM while
    ``simulate_epoch`` defaults to FM), so the constructor rejects
    anything that is not a valid ``MappingStrategy`` value.
    """

    backend: str
    strategy: str
    nominal_s: float            # fault-free epoch
    degraded_s: float           # epoch under non-fatal degradations
    loss_period: int | None     # first device-loss boundary (None = none)
    survivors: int              # cores after all losses at this step
    prefix_s: float             # work completed before the loss boundary
    re_transition_s: float      # state re-load onto the surviving window
    replanned_epoch_s: float    # Lemma-1 epoch on the surviving core set
    expected_s: float           # the headline number
    retry_s: float = 0.0        # wasted work re-done for TRANSIENT_RUN
    retries: int = 0            # total retry attempts priced

    def __post_init__(self) -> None:
        from repro.core.allocation import MappingStrategy

        try:
            normalized = MappingStrategy(self.strategy).value
        except ValueError:
            raise ValueError(
                f"FaultPricing.strategy {self.strategy!r} is not a "
                f"MappingStrategy value "
                f"({[s.value for s in MappingStrategy]})") from None
        if normalized != self.strategy:
            object.__setattr__(self, "strategy", normalized)

    @property
    def overhead_pct(self) -> float:
        return 100.0 * (self.expected_s / self.nominal_s - 1.0)


def _retransition_cost(workload: FCNNWorkload, cfg: ONoCConfig,
                       survivors: int, backend) -> float:
    """Price of re-loading the full model state onto the surviving window
    after a device loss (checkpoint replay, epoch-granular recovery).

    ONoC: one TDM round of per-sender setups (ceil(m'/λ) slots) plus the
    full-state payload streamed over the comb.  ENoC: the same payload
    drained at one link's effective bandwidth plus per-core setup —
    deliberately simple, documented models; both monotone in state size
    and decreasing in surviving-core bandwidth.
    """
    total_values = sum(
        (workload.n(i - 1) + 1) * workload.n(i)
        for i in range(1, workload.l + 1)
    )
    if getattr(backend, "name", "onoc") == "enoc":
        payload_bytes = total_values * cfg.bytes_per_value
        bw = backend.enoc.effective_link_bandwidth_Bps()
        return survivors * cfg.setup_time_s + payload_bytes / bw
    slots = math.ceil(survivors / cfg.lambda_max)
    return slots * cfg.setup_time_s + cfg.payload_time_s(total_values)


def expected_epoch_time(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    schedule: FaultSchedule,
    step: int | None = None,
    strategy="orrm",
    backend=None,
    refine_plateau: bool = True,
) -> FaultPricing:
    """Fault-aware epoch pricing on either backend.

    Without device loss the price is the degraded epoch (wavelength/link/
    straggler faults applied through ``EpochFaults``).  With device loss at
    period p the failure model is:

        E[T] = prefix(degraded, < p)        work completed before the loss
             + re_transition(survivors)     state re-load onto the window
             + T*(survivors)                Lemma-1 replanned epoch on the
                                            surviving core set (recovery is
                                            epoch-granular: the interrupted
                                            epoch restarts from checkpoint)

    which is exactly what the degraded-mode runner executes
    (runtime/degraded.py): replan, recompile, resume-from-checkpoint.

    TRANSIENT_RUN events are priced as retry waste: the supervisor's
    retry restarts the step from its beginning, so a transient at period
    p that fails ``count`` attempts re-does the degraded prefix through
    period p's RUN (compute of periods 1..p + transitions before p)
    ``count`` times.  With a device loss at boundary p_loss only
    transients strictly before p_loss are priced — later boundaries are
    never reached, and post-replan retries belong to the next epoch's
    price.  ``retry_s`` carries the total; ``expected_s`` includes it.
    """
    from repro.core.allocation import MappingStrategy
    from repro.core.simulator import ONoCBackend, simulate_epoch

    # normalize early: every priced component (nominal, degraded, retry
    # prefixes, the replanned epoch) must use one strategy, and the
    # resulting FaultPricing.strategy must name it exactly — note the
    # default here is "orrm" while simulate_epoch defaults to FM, so
    # cross-checks must pass pricing.strategy explicitly.
    strategy = MappingStrategy(strategy).value
    backend = backend or ONoCBackend()
    ef = EpochFaults.from_schedule(schedule, step)
    nominal = simulate_epoch(workload, cfg, strategy=strategy,
                             backend=backend)
    degraded = simulate_epoch(workload, cfg, strategy=strategy,
                              backend=backend, faults=ef)
    n_periods = 2 * workload.l

    def _retry_cost(before_period: int | None) -> tuple[float, int]:
        transients = (schedule.transient_runs(step) if step is not None
                      else schedule.transient_runs())
        total, n_retries = 0.0, 0
        for e in transients:
            p = min(max(e.period, 1), n_periods)  # 0 = first RUN boundary
            if before_period is not None and p >= before_period:
                continue
            n = max(e.count, 1)
            wasted = (sum(degraded.per_period_compute_s[:p])
                      + sum(t.comm_s for t in degraded.transitions
                            if t.period < p))
            total += n * wasted
            n_retries += n
        return total, n_retries

    losses = (schedule.device_losses(step) if step is not None
              else schedule.device_losses())
    if not losses:
        retry_s, retries = _retry_cost(None)
        return FaultPricing(
            backend=backend.name, strategy=nominal.strategy,
            nominal_s=nominal.total_s, degraded_s=degraded.total_s,
            loss_period=None, survivors=cfg.m, prefix_s=degraded.total_s,
            re_transition_s=0.0, replanned_epoch_s=0.0,
            expected_s=degraded.total_s + retry_s,
            retry_s=retry_s, retries=retries,
        )

    p = min(max(e.period, 1) for e in losses)
    survivors = cfg.m - len({e.device for e in losses})
    if survivors < 1:
        raise ValueError("device loss leaves no surviving cores")

    prefix = sum(degraded.per_period_compute_s[: p - 1])
    prefix += sum(t.comm_s for t in degraded.transitions if t.period < p)
    re_tr = _retransition_cost(workload, cfg, survivors, backend)
    retry_s, retries = _retry_cost(p)

    cfg_surv = dataclasses.replace(cfg, m=survivors)
    cores = optimal_cores(workload, cfg_surv, refine_plateau=refine_plateau)
    cores = [min(c, survivors) for c in cores]
    replanned = simulate_epoch(workload, cfg_surv, strategy=strategy,
                               cores_per_period=cores, backend=backend,
                               faults=ef)

    expected = prefix + retry_s + re_tr + replanned.total_s
    return FaultPricing(
        backend=backend.name, strategy=nominal.strategy,
        nominal_s=nominal.total_s, degraded_s=degraded.total_s,
        loss_period=p, survivors=survivors, prefix_s=prefix,
        re_transition_s=re_tr, replanned_epoch_s=replanned.total_s,
        expected_s=expected, retry_s=retry_s, retries=retries,
    )
