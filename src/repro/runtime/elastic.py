"""Elastic scaling: the ONoC allocator is the re-planning oracle.

When cluster membership changes (node loss / capacity grant), the
paper's model answers "how many workers should each stage use now?" —
Lemma 1 with the new m.  ``ElasticPlanner`` re-derives the allocation,
rebuilds the mesh + sharding rules, and the checkpointer's
restore-with-shardings moves the state onto the new layout.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core.onoc_model import FCNNWorkload, ONoCConfig, optimal_cores
from repro.core.allocation import MappingStrategy, map_cores


@dataclasses.dataclass
class ElasticPlanner:
    workload: FCNNWorkload
    base_cfg: ONoCConfig
    strategy: MappingStrategy = MappingStrategy.ORRM

    def plan_for(self, n_devices: int):
        """Re-run the paper's allocator for a new device count."""
        cfg = dataclasses.replace(self.base_cfg, m=n_devices)
        cores = optimal_cores(self.workload, cfg, refine_plateau=True)
        cores = [min(c, n_devices) for c in cores]
        mapping = map_cores(self.workload, cfg, self.strategy, cores)
        return cfg, cores, mapping

    def replan_program(self, n_devices: int, backend=None):
        """Degraded-mode replan: Lemma-1 plan on the surviving ring plus a
        freshly compiled (and statically validated) period program for it.

        Returns ``(cfg, plan, program)`` where ``cfg`` is the base config
        shrunk to ``n_devices`` cores.  ``compile_program`` re-runs the
        static verifier on the new schedule, so a bad replan is a hard
        ``ProgramValidationError`` before anything executes.
        """
        from repro.core.planner import plan_fcnn, ring_mesh_axes
        from repro.exec.program import compile_program

        cfg = dataclasses.replace(self.base_cfg, m=n_devices)
        plan = plan_fcnn(self.workload, cfg, ring_mesh_axes(n_devices),
                         strategy=self.strategy)
        program = compile_program(plan, self.workload, cfg, n_devices,
                                  backend=backend)
        return cfg, plan, program

    def make_mesh(self, devices=None, axis: str = "data") -> Mesh:
        devices = devices if devices is not None else jax.devices()
        return Mesh(np.asarray(devices), (axis,))

    def remesh_state(self, state: Any, old_mesh: Mesh, new_mesh: Mesh,
                     shardings_fn) -> Any:
        """Re-device_put a state pytree onto a new mesh.  shardings_fn maps
        a mesh to a same-structure pytree of NamedShardings."""
        target = shardings_fn(new_mesh)
        return jax.tree.map(
            lambda x, s: jax.device_put(np.asarray(x), s), state, target)
