"""Fault tolerance: checkpoint/restart supervision + straggler mitigation.

``TrainingSupervisor`` wraps a step function with:
  * periodic async checkpointing (atomic — see checkpoint/),
  * automatic restart from the latest complete checkpoint on failure
    (including data-pipeline state, so no sample is skipped or repeated),
  * bounded retry with exponential backoff for transient failures.

``StragglerMonitor`` implements deadline-based straggler mitigation at the
step granularity: a step exceeding ``deadline_factor`` × the trailing
median is treated as straggling; the registered mitigation callback fires
(on a real cluster: re-dispatch the shard / hot-swap the replica — the
multi-controller hook is ``on_straggler``; on CPU CI it's observed-only).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from collections import deque
from typing import Any, Callable

from repro.checkpoint import Checkpointer, latest_step

log = logging.getLogger(__name__)


@dataclasses.dataclass
class StragglerMonitor:
    deadline_factor: float = 3.0
    window: int = 32
    on_straggler: Callable[[int, float, float], None] | None = None
    _times: deque = dataclasses.field(default_factory=deque)
    straggler_steps: list[int] = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        # honor the configured window (the deque default can't see it)
        self._times = deque(self._times, maxlen=self.window)

    def observe(self, step: int, duration_s: float) -> bool:
        is_straggler = False
        if len(self._times) >= 8:
            med = sorted(self._times)[len(self._times) // 2]
            if duration_s > self.deadline_factor * med:
                is_straggler = True
                self.straggler_steps.append(step)
                log.warning("step %d straggled: %.3fs vs median %.3fs",
                            step, duration_s, med)
                if self.on_straggler is not None:
                    self.on_straggler(step, duration_s, med)
        self._times.append(duration_s)
        return is_straggler


@dataclasses.dataclass
class TrainingSupervisor:
    checkpointer: Checkpointer
    checkpoint_every: int = 100
    max_retries: int = 3
    backoff_s: float = 0.1
    straggler: StragglerMonitor = dataclasses.field(
        default_factory=StragglerMonitor)
    # Exception types the retry/restart loop must NOT swallow: they
    # propagate to the caller immediately.  The degraded-mode runner passes
    # (DeviceLossFault,) here — a lost device cannot be retried away, it
    # needs a replan + recompile (runtime/degraded.py).
    fatal: tuple[type, ...] = ()

    def latest(self) -> int | None:
        return latest_step(self.checkpointer.directory)

    def run(
        self,
        state: Any,
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        batches: Any,                      # iterator with state()/restore()
        n_steps: int,
        start_step: int = 0,
        restore_shardings: Any = None,
    ) -> tuple[Any, list[dict]]:
        """Run n_steps with checkpoint/restart.  step_fn(state, batch) ->
        (state, metrics)."""
        # resume if a checkpoint exists
        last = self.latest()
        step = start_step
        if last is not None and last >= start_step:
            meta = self.checkpointer.meta(last)
            state = self.checkpointer.restore(last, state, restore_shardings)
            if hasattr(batches, "restore") and "data_state" in meta:
                batches.restore(meta["data_state"])
            step = last + 1
            log.info("resumed from checkpoint step %d", last)

        history: list[dict] = []
        while step < start_step + n_steps:
            batch = next(batches)
            attempt = 0
            while True:
                try:
                    t0 = time.monotonic()
                    state, metrics = step_fn(state, batch)
                    dt = time.monotonic() - t0
                    break
                except Exception as e:                   # noqa: BLE001
                    if isinstance(e, self.fatal):
                        raise
                    attempt += 1
                    if attempt > self.max_retries:
                        # final fallback: restart from latest checkpoint
                        last = self.latest()
                        if last is None:
                            raise
                        log.exception(
                            "step %d failed %d times; restarting from %d",
                            step, attempt, last)
                        state = self.checkpointer.restore(
                            last, state, restore_shardings)
                        meta = self.checkpointer.meta(last)
                        if hasattr(batches, "restore") and "data_state" in meta:
                            batches.restore(meta["data_state"])
                        step = last + 1
                        batch = next(batches)
                        attempt = 0
                    time.sleep(self.backoff_s * (2 ** attempt))
            self.straggler.observe(step, dt)
            metrics = dict(metrics, step=step, seconds=dt)
            history.append(metrics)
            if self.checkpoint_every and (step + 1) % self.checkpoint_every == 0:
                extra = {}
                if hasattr(batches, "state"):
                    extra["data_state"] = batches.state()
                self.checkpointer.save(step, state, blocking=False,
                                       extra_meta=extra)
            step += 1
        self.checkpointer.wait()
        return state, history
