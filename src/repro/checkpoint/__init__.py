from .checkpointer import Checkpointer, latest_step  # noqa: F401
