"""Sharded, atomic, optionally-async checkpointing (npz-based).

Fault-tolerance contract:
  * atomic: writes go to ``<dir>/tmp.<step>`` then os.replace into
    ``<dir>/step_<n>`` — a crash mid-save never corrupts the latest
    checkpoint, restart picks up the newest complete step.
  * sharded: each leaf is saved as its own .npy inside the step directory
    (flattened tree paths), so per-leaf streaming restore never
    materializes the full state twice; on restore the leaf is device_put
    with the *target* sharding — which may belong to a different mesh than
    the one that saved it (elastic re-mesh).
  * async: ``save(..., blocking=False)`` snapshots to host then hands the
    write to a background thread; ``wait()`` joins before the next save.
  * self-describing: tree structure + dtypes + step metadata in
    ``manifest.json``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

_SEP = "::"


def _flatten(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = leaf
    return flat


def _key_str(k) -> str:
    if isinstance(k, jax.tree_util.DictKey):
        return str(k.key)
    if isinstance(k, jax.tree_util.SequenceKey):
        return f"[{k.idx}]"
    if isinstance(k, jax.tree_util.GetAttrKey):
        return k.name
    return str(k)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, blocking: bool = True,
             extra_meta: dict | None = None) -> None:
        self.wait()
        flat = _flatten(state)
        # snapshot to host memory first (device buffers may be donated next step)
        host = {k: np.asarray(v) for k, v in flat.items()}
        treedef = jax.tree_util.tree_structure(state)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "keys": sorted(host),
            **(extra_meta or {}),
        }

        def _write():
            tmp = os.path.join(self.directory, f"tmp.{step}")
            final = os.path.join(self.directory, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for k, v in host.items():
                np.save(os.path.join(tmp, _fname(k)), v)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            shutil.rmtree(final, ignore_errors=True)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d)))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; optional target shardings
        (same-structure pytree of jax.sharding.Sharding) support restoring
        onto a different mesh than the checkpoint was saved from."""
        d = os.path.join(self.directory, f"step_{step}")
        if not os.path.isdir(d):
            raise FileNotFoundError(d)
        flat_like = _flatten(like)
        flat_shard = _flatten(shardings) if shardings is not None else {}
        restored = {}
        for k, leaf in flat_like.items():
            arr = np.load(os.path.join(d, _fname(k)))
            if k in flat_shard and flat_shard[k] is not None:
                restored[k] = jax.device_put(arr, flat_shard[k])
            else:
                restored[k] = jax.numpy.asarray(arr, dtype=leaf.dtype)
        # rebuild tree in `like`'s structure
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        paths = [
            _SEP.join(_key_str(kk) for kk in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
        ]
        return jax.tree_util.tree_unflatten(
            treedef, [restored[p] for p in paths])

    def meta(self, step: int) -> dict:
        with open(os.path.join(self.directory, f"step_{step}",
                               "manifest.json")) as f:
            return json.load(f)


def _fname(key: str) -> str:
    return key.replace(_SEP, "__").replace("/", "_") + ".npy"
