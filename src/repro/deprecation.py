"""Warn-once deprecation helper.

The deprecated execution-engine shims (``exec.runtime.build_train_step``,
``launch.steps.build_fcnn_program_step``) are kept as thin wrappers over
``repro.exec.compile`` for old callers — typically invoked inside
training loops, where a per-call ``DeprecationWarning`` floods logs.
``warn_deprecated`` emits each keyed warning exactly once per process;
``reset`` re-arms it (tests asserting the warning fires).

Python's own ``warnings`` default filter dedupes per *location*, but that
state is invisible and routinely overridden by pytest/absl filters —
an explicit key set is deterministic either way.
"""

from __future__ import annotations

import warnings

__all__ = ["warn_deprecated", "reset"]

_warned: set[str] = set()


def warn_deprecated(key: str, message: str, stacklevel: int = 3) -> None:
    """Emit ``DeprecationWarning(message)`` once per ``key`` per process.

    ``stacklevel`` defaults to 3: the caller of the deprecated shim, not
    the shim itself.
    """
    if key in _warned:
        return
    _warned.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=stacklevel)


def reset(key: str | None = None) -> None:
    """Re-arm one key (or all, when ``key`` is None)."""
    if key is None:
        _warned.clear()
    else:
        _warned.discard(key)
