"""Batched serving driver: prefill + decode loop with a continuous-batching
slot manager.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --smoke \
      --requests 8 --prompt-len 32 --gen 16

The slot manager packs requests into a fixed device batch; finished
sequences release their slot to queued requests (the vLLM-style pattern at
the granularity XLA likes: fixed shapes, slot reuse).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.data import token_stream
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class SlotManager:
    """Continuous batching over a fixed-size device batch."""

    def __init__(self, n_slots: int):
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.finished: list[Request] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def fill(self) -> list[int]:
        """Assign queued requests to free slots; returns newly filled."""
        new = []
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                self.slots[i] = self.queue.pop(0)
                new.append(i)
        return new

    def release_done(self) -> None:
        for i, s in enumerate(self.slots):
            if s is not None and s.done:
                self.finished.append(s)
                self.slots[i] = None

    @property
    def active(self) -> bool:
        return any(self.slots) or bool(self.queue)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit("serve.py drives token-LM archs")
    mesh = make_host_mesh()
    model = get_model(cfg)
    max_len = args.prompt_len + args.gen
    if cfg.family in ("ssm", "hybrid"):
        # chunked prefill wants seq % chunk == 0
        args.prompt_len = max(cfg.ssm_chunk,
                              (args.prompt_len // cfg.ssm_chunk) * cfg.ssm_chunk)
        max_len = args.prompt_len + args.gen

    shape = ShapeSpec("serve", args.prompt_len, args.slots, "prefill")
    with mesh:
        prefill, p_sh, _, c_sh = steps_lib.build_prefill_step(
            model, mesh, shape, max_len=max_len)
        decode, *_ = steps_lib.build_decode_step(
            model, mesh,
            ShapeSpec("serve", max_len, args.slots, "decode"))
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), p_sh)

        # synth requests
        stream = token_stream(args.requests * args.prompt_len,
                              cfg.vocab_size, seed=1)
        mgr = SlotManager(args.slots)
        for r in range(args.requests):
            mgr.submit(Request(
                rid=r,
                prompt=stream[r * args.prompt_len:(r + 1) * args.prompt_len],
                max_new=args.gen))

        t0 = time.time()
        n_prefills = n_decodes = 0
        cache = None
        last_tokens = np.zeros((args.slots, 1), np.int32)
        while mgr.active:
            newly = mgr.fill()
            if newly:
                # batch prefill for the whole slot set (fixed shape); slots
                # without a request run garbage that is never read.
                prompts = np.stack([
                    s.prompt if s is not None else
                    np.zeros(args.prompt_len, np.int32)
                    for s in mgr.slots])
                logits, cache = prefill(params, {"tokens": jnp.asarray(prompts)})
                n_prefills += 1
                nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
                for i, s in enumerate(mgr.slots):
                    if s is not None and not s.out:
                        s.out.append(int(nxt[i, 0]))
                last_tokens = nxt
            logits, cache = decode(params, cache,
                                   {"tokens": jnp.asarray(last_tokens)})
            n_decodes += 1
            nxt = np.asarray(jnp.argmax(logits, -1)).astype(np.int32)
            for i, s in enumerate(mgr.slots):
                if s is None or s.done:
                    continue
                s.out.append(int(nxt[i, 0]))
                if len(s.out) >= s.max_new:
                    s.done = True
            last_tokens = nxt
            mgr.release_done()
            # simple batch-boundary refill: only refill when all slots idle
            if not any(s is not None and not s.done for s in mgr.slots):
                mgr.release_done()

        dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in mgr.finished)
    print(f"{cfg.name}: served {len(mgr.finished)} requests, "
          f"{total_tokens} tokens in {dt:.2f}s "
          f"({n_prefills} prefills, {n_decodes} decode steps, "
          f"{total_tokens / max(dt, 1e-9):.1f} tok/s)")
    for r in mgr.finished[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
