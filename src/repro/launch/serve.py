"""Serving CLI — a thin launcher over the ``repro.serve`` subsystem.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-14b --smoke \
      --scenario steady --requests 8 --seed 0

Replays a seeded traffic scenario (see ``repro.serve.traffic`` presets:
steady | burst | drain | device-loss-mid-decode) through the
continuous-batching engine and prints the SLO report.  ``--json PATH``
dumps the report + per-request records for offline analysis.

The old in-module prototype (whole-batch refill SlotManager + inline
serve loop) moved to ``repro.serve.scheduler`` — and the refill path was
fixed on the way: admission now prefills per-slot and merges only that
slot's cache rows, so an in-flight request's KV state is never clobbered
by someone else's admission.  ``SlotManager`` / ``Request`` stay
importable from here as warn-once deprecation shims.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

from repro.configs import get_config, smoke_config

_DEPRECATED = {
    "SlotManager": "launch.serve.SlotManager",
    "Request": "launch.serve.Request",
}


def __getattr__(name: str):
    if name in _DEPRECATED:
        from repro.deprecation import warn_deprecated
        from repro.serve import scheduler

        warn_deprecated(
            _DEPRECATED[name],
            f"repro.launch.serve.{name} is deprecated; import it from "
            f"repro.serve (the promoted serving subsystem)")
        return getattr(scheduler, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def main() -> None:
    from repro.serve import (
        JaxModelRunner,
        SCENARIO_NAMES,
        ServeAutoscaler,
        ServingEngine,
        make_traffic,
        scenario_preset,
        snap_prompt_buckets,
    )

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--scenario", default="steady", choices=SCENARIO_NAMES)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=None,
                    help="override the preset's request count")
    ap.add_argument("--rate", type=float, default=None,
                    help="override the preset's arrival rate (req/s)")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.requests is not None:
        overrides["n_requests"] = args.requests
    if args.rate is not None:
        overrides["rate_rps"] = args.rate
    sc = scenario_preset(args.scenario, **overrides)
    sc = sc.replace(prompt_buckets=snap_prompt_buckets(cfg, sc.prompt_buckets))
    trace = make_traffic(sc, args.seed)

    runner = JaxModelRunner(cfg, n_slots=args.slots, max_len=sc.max_len)
    runner.warmup(sc.prompt_buckets)
    autoscaler = ServeAutoscaler(runner.n_devices, args.slots)
    engine = ServingEngine(runner, n_slots=args.slots, autoscaler=autoscaler)
    result = engine.run(trace, sc)

    slo = result.slo
    print(f"{cfg.name} · scenario={sc.name} seed={args.seed} "
          f"slots={args.slots} devices={runner.n_devices}")
    print(f"  served {slo.n_finished}/{slo.n_submitted} requests "
          f"({result.n_prefills} prefills, {result.n_decode_steps} decode "
          f"steps, {slo.n_restarts} restarts, {len(result.replans)} "
          f"replans) in {slo.makespan_s:.3f}s")
    print(f"  TTFT p50/p99 {slo.p50_ttft_s * 1e3:.1f}/"
          f"{slo.p99_ttft_s * 1e3:.1f} ms · TPOT p50/p99 "
          f"{slo.p50_tpot_s * 1e3:.2f}/{slo.p99_tpot_s * 1e3:.2f} ms · "
          f"e2e p99 {slo.p99_e2e_s * 1e3:.1f} ms")
    print(f"  throughput {slo.throughput_tok_s:.1f} tok/s · goodput "
          f"{slo.goodput_tok_s:.1f} tok/s ({slo.n_slo_ok}/{slo.n_finished} "
          f"within TTFT<={sc.ttft_slo_s}s, TPOT<={sc.tpot_slo_s}s)")
    for rp in result.replans:
        print(f"  replan[{rp.reason}] devices {rp.from_devices}->"
              f"{rp.to_devices} slots {rp.from_slots}->{rp.to_slots} "
              f"(Lemma-1 cores {rp.lemma1_cores}, epoch {rp.epoch_s})")
    for rid in sorted(result.streams)[:3]:
        print(f"  req {rid}: {result.streams[rid][:8]}...")

    if args.json:
        payload = {
            "arch": cfg.name,
            "scenario": dataclasses.asdict(sc),
            "seed": args.seed,
            "slots": args.slots,
            "slo": slo.to_row(),
            "replans": [rp.to_dict() for rp in result.replans],
            "requests": [dataclasses.asdict(r)
                         for r in result.metrics.records.values()],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# json report -> {args.json}")


if __name__ == "__main__":
    main()
