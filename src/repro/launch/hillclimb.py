"""§Perf hillclimb driver: run named experiment variants of one
(arch × shape × mesh) cell and log the three roofline terms per variant.

  PYTHONPATH=src python -m repro.launch.hillclimb --arch qwen2.5-14b \
      --shape train_4k --variants baseline,vp_embed,vp_embed+dots

Variants compose rule overrides + config/settings tweaks (see VARIANTS).
Results are appended to results/hillclimb.json.
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

from repro.configs import get_config       # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.dryrun import run_cell    # noqa: E402

# each variant: (rule_overrides, cfg_overrides, settings_overrides)
VARIANTS: dict[str, tuple[dict, dict, dict]] = {
    "baseline": ({}, {}, {}),
    # vocab-parallel embedding: table sharded on vocab only — kills the
    # SPMD involuntary-full-remat on the token gather
    "vp_embed": ({"table_embed": None}, {}, {}),
    # remat policy: save matmul outputs (incl. post-collective tensors) so
    # the backward recompute repeats no collectives
    "dots": ({}, {"remat_policy": "dots"}, {}),
    "noremat": ({}, {"remat": False}, {}),
    # Megatron-SP: residual stream sequence-sharded on "model" between
    # blocks (AR -> RS+AG pairs, 1/16th resident activations)
    "seqpar": ({"residual_length": "model"}, {}, {}),
    # microbatched gradient accumulation (memory lever)
    "micro4": ({}, {}, {"microbatches": 4}),
    # int8 gradient compression (pod-axis gradient reduction 4x lighter)
    "int8grad": ({}, {}, {"grad_compression": "int8"}),
    # no FSDP: weights replicated over "data" (for small models the
    # per-layer weight all-gathers cost more than the memory saved)
    "nofsdp": ({"embed": None, "table_embed": None}, {}, {}),
    # combos
    "vp+seqpar": ({"table_embed": None, "residual_length": "model"}, {}, {}),
    "vp+nofsdp": ({"table_embed": None, "embed": None}, {}, {}),
    "vp+seqpar+nofsdp": ({"table_embed": None, "residual_length": "model",
                          "embed": None}, {}, {}),
    "vp+seqpar+micro4": ({"table_embed": None, "residual_length": "model"},
                         {}, {"microbatches": 4}),
    "vp+dots": ({"table_embed": None}, {"remat_policy": "dots"}, {}),
    "vp+seqpar+dots": ({"table_embed": None, "residual_length": "model"},
                       {"remat_policy": "dots"}, {}),
    # replicate GQA kv heads (8 does not divide model=16; uneven sharding
    # makes the attention backward all-gather FULL-BATCH K/V grads)
    "kv_rep": ({"kv_heads": None, "activation_kv_heads": None}, {}, {}),
    "kv_rep+dots": ({"kv_heads": None, "activation_kv_heads": None},
                    {"remat_policy": "dots"}, {}),
    "kv_rep+dots+micro4": ({"kv_heads": None, "activation_kv_heads": None},
                           {"remat_policy": "dots"}, {"microbatches": 4}),
    "kv_rep+micro4": ({"kv_heads": None, "activation_kv_heads": None},
                      {}, {"microbatches": 4}),
    # bf16 cross-shard partial sums / backward ARs (halves AR bytes)
    "kv_rep+bf16comm": ({"kv_heads": None, "activation_kv_heads": None},
                        {"accum_dtype": "bfloat16"}, {}),
    "kv_rep+bf16comm+micro4": (
        {"kv_heads": None, "activation_kv_heads": None},
        {"accum_dtype": "bfloat16"}, {"microbatches": 4}),
    "kv_rep+bf16comm+dots+micro4": (
        {"kv_heads": None, "activation_kv_heads": None},
        {"accum_dtype": "bfloat16", "remat_policy": "dots"},
        {"microbatches": 4}),
    "kv_rep+bf16comm+micro8": (
        {"kv_heads": None, "activation_kv_heads": None},
        {"accum_dtype": "bfloat16"}, {"microbatches": 8}),
    "kv_rep+vp+bf16comm+micro8": (
        {"kv_heads": None, "activation_kv_heads": None, "table_embed": None},
        {"accum_dtype": "bfloat16"}, {"microbatches": 8}),
    "kv_rep+bf16comm+dots+micro8": (
        {"kv_heads": None, "activation_kv_heads": None},
        {"accum_dtype": "bfloat16", "remat_policy": "dots"},
        {"microbatches": 8}),
    "kv_rep+bf16comm+dots+micro4b": (
        {"kv_heads": None, "activation_kv_heads": None},
        {"accum_dtype": "bfloat16", "remat_policy": "dots"},
        {"microbatches": 4}),
    # pure FSDP: batch over data*model (1 seq/device at train_4k), weights
    # stay 2D-sharded and are gathered per layer; NO tensor-parallel
    # activations so the Megatron activation all-reduces vanish entirely
    "pure_fsdp": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None, "activation_vocab": None,
         "activation_exp": None, "kv_heads": None},
        {}, {}),
    "pure_fsdp+vp": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None, "activation_vocab": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {}, {}),
    "pure_fsdp+vp+bf16comm": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None, "activation_vocab": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"accum_dtype": "bfloat16"}, {}),
    # pure FSDP but logits stay vocab-sharded + chunked attention at 4k
    "pure_fsdp+vTP+chunk": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"attn_chunk_threshold": 2048 * 2048}, {}),
    "pure_fsdp+vTP+chunk+bf16comm": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"attn_chunk_threshold": 2048 * 2048, "accum_dtype": "bfloat16"},
        {}),
    "pure_fsdp+fce+chunk": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None, "activation_vocab": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"attn_chunk_threshold": 2048 * 2048, "fused_ce": True}, {}),
    "pure_fsdp+fce+chunk+bf16comm": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None, "activation_vocab": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"attn_chunk_threshold": 2048 * 2048, "fused_ce": True,
         "accum_dtype": "bfloat16"}, {}),
    "pure_fsdp+fce+oh+chunk": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None, "activation_vocab": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"attn_chunk_threshold": 2048 * 2048, "fused_ce": True,
         "embed_onehot": True}, {}),
    # serving layout: weights 2D-TP (mlp over model*data), nothing gathered
    # per step; decode activations are tiny so resharding them is free
    "serve_2dtp": (
        {"embed": None, "table_embed": None, "mlp": ("model", "data")},
        {}, {}),
    "serve_2dtp+bf16comm": (
        {"embed": None, "table_embed": None, "mlp": ("model", "data")},
        {"accum_dtype": "bfloat16"}, {}),
    "serve_bf16comm": ({}, {"accum_dtype": "bfloat16"}, {}),
    # + replicate decode activations (tiny); h replicated x 2D-sharded W
    # has no sharding conflict, so nothing is gathered at all
    "serve_2dtp_repb": (
        {"embed": None, "table_embed": None, "mlp": ("model", "data"),
         "activation_mlp": ("model", "data"), "activation_batch": None,
         "activation_vocab": ("model", "data"), "vocab": ("model", "data")},
        {}, {}),
    "pure_fsdp+vTP+chunk+micro2": (
        {"activation_batch": ("pod", "data", "model"),
         "cache_batch": ("pod", "data", "model"),
         "activation_heads": None, "activation_kv_heads": None,
         "activation_mlp": None,
         "activation_exp": None, "kv_heads": None, "table_embed": None},
        {"attn_chunk_threshold": 2048 * 2048}, {"microbatches": 2}),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["pod", "multipod"], default="pod")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="results/hillclimb.json")
    args = ap.parse_args()

    multi_pod = args.mesh == "multipod"
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for variant in args.variants.split(","):
        rules_ov, cfg_ov, set_ov = VARIANTS[variant]
        key = f"{args.arch}|{args.shape}|{args.mesh}|{variant}"
        if results.get(key, {}).get("ok"):
            print(f"[cached] {key}")
            continue
        print(f"[run] {key}", flush=True)
        cfg = get_config(args.arch)
        if cfg_ov:
            cfg = cfg.replace(**cfg_ov)
        settings = steps_lib.TrainSettings(**set_ov) if set_ov else None
        t0 = time.time()
        try:
            res = run_cell(args.arch, args.shape, multi_pod, cfg=cfg,
                           rule_overrides=rules_ov, settings=settings)
            res["variant"] = variant
            results[key] = res
            print(f"  compute={res['compute_s']*1e3:.1f}ms "
                  f"memory={res['memory_s']*1e3:.1f}ms "
                  f"collective={res['collective_s']*1e3:.1f}ms "
                  f"hbm={res['peak_memory_per_device']/1e9:.1f}GB "
                  f"({time.time()-t0:.0f}s)", flush=True)
        except Exception as e:  # noqa: BLE001
            results[key] = {"ok": False, "variant": variant,
                            "error": f"{type(e).__name__}: {e}"}
            print(f"  FAIL {type(e).__name__}: {e}", flush=True)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
