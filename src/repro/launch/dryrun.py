"""Multi-pod dry-run: prove the distribution config is coherent by
lowering + compiling every (architecture × input shape × mesh) cell and
extracting the roofline terms from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh pod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out results/dryrun.json

Results are merged into the --out JSON (incremental across invocations).
"""

# The VERY FIRST lines — before ANY other import, jax locks device count
# on first init.  512 host devices cover both the 16x16 pod and the
# 2x16x16 multi-pod mesh.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse          # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, shape_cells  # noqa: E402
from repro.configs.base import ModelConfig, ShapeSpec  # noqa: E402
from repro.core.planner import TPUTarget  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.parallel.sharding import AxisRules, DEFAULT_RULES  # noqa: E402


# ---------------------------------------------------------------- helpers

_COLLECTIVE_RE = re.compile(
    r"\b(all-gather-start|all-gather|all-reduce-start|all-reduce"
    r"|reduce-scatter|all-to-all|collective-permute-start"
    r"|collective-permute)\b")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|s16|s8|u64|u32|u16|u8|pred)"
                       r"\[([\d,]*)\]")
_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1}


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, float]:
    """Sum RESULT sizes of every collective op in the (per-device) HLO.

    Lines look like:  %ag = bf16[8,1024]{1,0} all-gather(...), ...
    The result shape of an op line is the first shape on the line; for
    started async pairs we count the -start op only.
    """
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue  # async pairs: count the -start half only
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(1).replace("-start", "")
        sm = _SHAPE_RE.search(line)
        if not sm:
            continue
        out[op] = out.get(op, 0.0) + _shape_bytes(sm.group(1), sm.group(2))
    return out


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens


def param_count(cfg: ModelConfig) -> float:
    """Total parameters (approximate closed form per family)."""
    d, l, v = cfg.d_model, cfg.n_layers, cfg.vocab_size
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family in ("dense", "vlm"):
        hd = cfg.resolved_head_dim
        attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
        mlp = 3 * d * cfg.d_ff
        return emb + l * (attn + mlp)
    if cfg.family == "moe":
        hd = cfg.resolved_head_dim
        attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
        moe = cfg.n_experts * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
        shared = 3 * d * cfg.n_shared_experts * cfg.moe_d_ff
        return emb + l * (attn + moe + shared)
    if cfg.family == "ssm":
        di, g, n_s, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        proj = d * (2 * di + 2 * g * n_s + h) + di * d
        return emb + l * proj
    if cfg.family == "hybrid":
        di, g, n_s, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        mamba = d * (2 * di + 2 * g * n_s + h) + di * d
        hd = cfg.resolved_head_dim
        shared = (2 * d) * d + d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) \
            + cfg.n_heads * hd * d + 3 * d * cfg.d_ff
        return emb + l * mamba + shared
    if cfg.family == "encdec":
        hd = cfg.resolved_head_dim
        attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
        mlp = 3 * d * cfg.d_ff
        enc = cfg.n_encoder_layers * (attn + mlp)
        dec = cfg.n_layers * (2 * attn + mlp)
        return emb + enc + dec
    raise ValueError(cfg.family)


def active_param_count(cfg: ModelConfig) -> float:
    """Active params per token (MoE: top-k of E experts)."""
    total = param_count(cfg)
    if cfg.family != "moe":
        return total
    d, l = cfg.d_model, cfg.n_layers
    all_experts = l * cfg.n_experts * 3 * d * cfg.moe_d_ff
    active_experts = l * cfg.experts_per_token * 3 * d * cfg.moe_d_ff
    return total - all_experts + active_experts


# ---------------------------------------------------------------- lowering

# The §Perf-winning recipes, applied by ``--plan optimized``.  Family-aware
# (validated per cell, EXPERIMENTS.md §Perf):
#   train/dense+vlm+ssm+hybrid+encdec — pure-FSDP layout (batch over every
#     mesh axis, no TP activations, 2D-sharded weights) + fused CE +
#     one-hot embed + chunked flash attention: 2–19×.
#   train/moe — pure-FSDP breaks the grouped expert dispatch (measured
#     0.13×); kv-replication only (1.6×).
#   prefill — already memory-bound; overrides are a wash (±1%): baseline.
#   decode/dense+vlm — 2D-TP weights, replicated per-token activations
#     (flash-decoding cache rules from _rules_for still apply): 1.3–5.3×.
#   decode/ssm+hybrid+moe+encdec — baseline already near-optimal; the
#     serve overrides regressed them (0.2–0.9×): baseline.
_TRAIN_PURE_FSDP = (
    {"activation_batch": ("pod", "data", "model"),
     "cache_batch": ("pod", "data", "model"),
     "activation_heads": None, "activation_kv_heads": None,
     "activation_mlp": None, "activation_vocab": None,
     "activation_exp": None, "kv_heads": None, "table_embed": None},
    {"attn_chunk_threshold": 2048 * 2048, "fused_ce": True,
     "embed_onehot": True},
)
_TRAIN_KV_REP = (
    {"kv_heads": None, "activation_kv_heads": None},
    {},
)
_DECODE_SERVE = (
    {"embed": None, "table_embed": None, "mlp": ("model", "data"),
     "activation_mlp": ("model", "data"), "activation_batch": None,
     "activation_vocab": ("model", "data"), "vocab": ("model", "data")},
    {},
)
_BASELINE = ({}, {})


def optimized_plan(kind: str, family: str,
                   n_kv_heads: int = 0, model_ways: int = 16
                   ) -> tuple[dict, dict]:
    if kind == "train":
        if family == "moe":
            # kv replication only pays when kv-heads don't divide the TP
            # axis (measured: 1.6× for granite-moe kv=8, 0.85× for
            # qwen2-moe kv=16)
            if n_kv_heads and n_kv_heads % model_ways != 0:
                return _TRAIN_KV_REP
            return _BASELINE
        return _TRAIN_PURE_FSDP
    if kind == "decode" and family in ("dense", "vlm"):
        return _DECODE_SERVE
    return _BASELINE


def _rules_for(cfg: ModelConfig, shape: ShapeSpec, mesh) -> AxisRules:
    rules = DEFAULT_RULES
    data_ways = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            data_ways *= mesh.shape[a]
    if shape.global_batch < data_ways:
        # batch too small to shard (long_500k b=1): replicate batch axes
        rules = rules.override(activation_batch=None, cache_batch=None)
    model_ways = mesh.shape.get("model", 1)
    if (shape.kind == "decode" and cfg.n_kv_heads
            and cfg.n_kv_heads % model_ways != 0):
        # GQA kv-heads don't divide the model axis: head-sharded decode
        # attention would force GSPMD to all-reduce (B, S_cache, D)-sized
        # partials per layer.  Shard the cache on LENGTH instead — the
        # flash-decoding split-KV layout: each model shard scores its cache
        # slice, softmax becomes a distributed (max, sum) pair and PV a
        # partial-sum all-reduce, all of per-token size.  The cache divides
        # 16 ways so it fits HBM.
        rules = rules.override(activation_heads=None,
                               activation_kv_heads=None,
                               cache_kv_heads=None,
                               cache_length="model")
    return rules


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg: ModelConfig | None = None,
               rule_overrides: dict | None = None,
               settings=None):
    cfg = cfg or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = get_model(cfg)
    rules = _rules_for(cfg, shape, mesh)
    if rule_overrides:
        rules = rules.override(**rule_overrides)

    from repro.parallel.sharding import use_rules
    from repro.models.layers import use_accum_dtype

    with mesh, use_rules(rules), use_accum_dtype(cfg.accum_dtype):
        if shape.kind == "train":
            settings = settings or steps_lib.TrainSettings()
            step, st_sh, b_sh, state_spec = steps_lib.build_train_step(
                model, mesh, shape, settings, rules)
            lowered = step.lower(state_spec, model.input_specs(shape))
        elif shape.kind == "prefill":
            step, p_sh, b_sh, c_sh = steps_lib.build_prefill_step(
                model, mesh, shape, rules=rules)
            p_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            lowered = step.lower(p_spec, model.input_specs(shape))
        else:  # decode: one token against a seq_len-deep cache
            step, p_sh, b_sh, c_sh = steps_lib.build_decode_step(
                model, mesh, shape, rules=rules)
            p_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            kw = {}
            if cfg.family == "encdec":
                kw["enc_len"] = shape.seq_len // 2
            c_spec = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         **kw))
            lowered = step.lower(p_spec, c_spec, model.input_specs(shape))
    return lowered, mesh, cfg, shape


def _metrics_of(compiled) -> dict[str, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = cost or {}
    m = {"flops": float(cost.get("flops", 0.0)),
         "bytes": float(cost.get("bytes accessed", 0.0))}
    for k, v in collective_bytes_from_hlo(compiled.as_text()).items():
        m[f"coll:{k}"] = v
    return m


def _lin(*terms: tuple[float, dict]) -> dict[str, float]:
    out: dict[str, float] = {}
    for c, m in terms:
        for k, v in m.items():
            out[k] = out.get(k, 0.0) + c * v
    return {k: max(0.0, v) for k, v in out.items()}


def _probe_correct(arch: str, shape_name: str, multi_pod: bool,
                   cfg: ModelConfig,
                   rule_overrides: dict | None = None,
                   settings=None) -> dict[str, float]:
    """Exact loop-trip correction for XLA's count-loop-bodies-once cost
    analysis: compile 2-3 tiny fully-unrolled probe variants, solve the
    linear system for per-layer body cost, reconstruct the full total.
    (Validated: scan bodies are counted once; unroll=True is exact.)"""

    def probe(**over) -> dict[str, float]:
        pcfg = cfg.replace(probe_unroll=True, **over)
        lowered, *_ = lower_cell(arch, shape_name, multi_pod, cfg=pcfg,
                                 rule_overrides=rule_overrides,
                                 settings=settings)
        return _metrics_of(lowered.compile())

    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "ssm"):
        a = probe(n_layers=1)
        b = probe(n_layers=2)
        l = cfg.n_layers
        return _lin((2.0 - l, a), (l - 1.0, b))
    if fam == "hybrid":
        a = probe(n_layers=2, shared_attn_every=2)   # o + 2x + y
        b = probe(n_layers=4, shared_attn_every=2)   # o + 4x + 2y
        c = probe(n_layers=2, shared_attn_every=3)   # o + 2x
        # x = (b - 2a + c)/2 ; y = a - c ; total = c + (L-2)x + inv·y
        l = cfg.n_layers
        inv = l // (cfg.shared_attn_every or l)
        return _lin((1.0, c),
                    ((l - 2) / 2.0, b), (-(l - 2), a), ((l - 2) / 2.0, c),
                    (inv, a), (-inv, c))
    if fam == "encdec":
        a = probe(n_layers=1, n_encoder_layers=1)
        b = probe(n_layers=1, n_encoder_layers=2)
        c = probe(n_layers=2, n_encoder_layers=1)
        le, ld = cfg.n_encoder_layers, cfg.n_layers
        return _lin((1.0, a), (le - 1.0, b), (-(le - 1.0), a),
                    (ld - 1.0, c), (-(ld - 1.0), a))
    raise ValueError(fam)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tpu: TPUTarget = TPUTarget(),
             cfg: ModelConfig | None = None,
             rule_overrides: dict | None = None,
             settings=None, plan: str = "baseline") -> dict:
    if plan == "optimized":
        base = cfg or get_config(arch)
        rules_ov, cfg_ov = optimized_plan(SHAPES[shape_name].kind,
                                          base.family, base.n_kv_heads)
        rule_overrides = {**rules_ov, **(rule_overrides or {})}
        cfg = base.replace(**cfg_ov)
    t0 = time.time()
    lowered, mesh, cfg, shape = lower_cell(
        arch, shape_name, multi_pod, cfg=cfg,
        rule_overrides=rule_overrides, settings=settings)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    chips = mesh.size
    mem = compiled.memory_analysis()
    raw = _metrics_of(compiled)

    t0 = time.time()
    try:
        corrected = _probe_correct(arch, shape_name, multi_pod, cfg,
                                   rule_overrides=rule_overrides,
                                   settings=settings)
        probe_ok = True
    except Exception as e:  # noqa: BLE001
        print(f"  probe correction failed ({type(e).__name__}: {e}); "
              "using raw loop-once metrics")
        corrected, probe_ok = raw, False
    t_probe = time.time() - t0

    flops_dev = corrected["flops"]
    bytes_dev = corrected["bytes"]
    coll = {k.split(":", 1)[1]: v for k, v in corrected.items()
            if k.startswith("coll:")}
    coll_bytes_dev = float(sum(coll.values()))

    compute_s = flops_dev / tpu.peak_flops
    memory_s = bytes_dev / tpu.hbm_bw
    collective_s = coll_bytes_dev / tpu.ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_dev * chips
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes_dev,
        "collectives": coll,
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops": mf,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "peak_memory_per_device": _mem_bytes(mem),
        "raw_loop_once": raw,
        "probe_corrected": probe_ok,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "probe_s": round(t_probe, 1),
        "ok": True,
    }
    return result


def _mem_bytes(mem) -> float:
    """Live per-device bytes: args + outputs + temps − aliased (donated
    buffers are both argument and output; counting them twice would report
    2× for the KV cache / train state)."""
    if mem is None:
        return 0.0
    total = (getattr(mem, "argument_size_in_bytes", 0)
             + getattr(mem, "output_size_in_bytes", 0)
             + getattr(mem, "temp_size_in_bytes", 0)
             - getattr(mem, "alias_size_in_bytes", 0))
    return float(total)


# ---------------------------------------------------------------- driver

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--plan", choices=["baseline", "optimized"],
                    default="baseline")
    args = ap.parse_args()

    cells: list[tuple[str, str]] = []
    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        for shape_name, runnable, reason in shape_cells(cfg):
            if args.shape and shape_name != args.shape:
                continue
            cells.append((arch, shape_name) if runnable
                         else (arch, f"SKIP:{shape_name}:{reason}"))

    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch, shape_name in cells:
        if shape_name.startswith("SKIP:"):
            _, sname, reason = shape_name.split(":", 2)
            for mp in meshes:
                key = f"{arch}|{sname}|{'2x16x16' if mp else '16x16'}"
                results[key] = {"arch": arch, "shape": sname,
                                "mesh": "2x16x16" if mp else "16x16",
                                "ok": True, "skipped": True, "reason": reason}
                print(f"[skip] {key}: {reason}")
            continue
        for mp in meshes:
            key = f"{arch}|{shape_name}|{'2x16x16' if mp else '16x16'}"
            if results.get(key, {}).get("ok") and not results[key].get("skipped"):
                print(f"[cached] {key}")
                continue
            print(f"[run] {key} ...", flush=True)
            try:
                res = run_cell(arch, shape_name, mp, plan=args.plan)
                results[key] = res
                print(f"  ok: compute={res['compute_s']*1e3:.2f}ms "
                      f"memory={res['memory_s']*1e3:.2f}ms "
                      f"collective={res['collective_s']*1e3:.2f}ms "
                      f"bottleneck={res['bottleneck']} "
                      f"(lower {res['lower_s']}s compile {res['compile_s']}s)",
                      flush=True)
            except Exception as e:  # noqa: BLE001
                results[key] = {"arch": arch, "shape": shape_name,
                                "mesh": "2x16x16" if mp else "16x16",
                                "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"  FAIL: {type(e).__name__}: {e}")
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} cells ok -> {args.out}")


if __name__ == "__main__":
    main()
