"""jit-compiled train / serve step builders with full sharding closure.

``build_train_step``: (TrainState, batch) -> (TrainState, metrics), with
in/out shardings derived from the model's logical axes (shape-aware: axes
that don't divide are demoted — see parallel.sharding.resolve_spec),
donated state, and optional int8 gradient compression (error-feedback
residual rides in the state).

``build_prefill_step`` / ``build_decode_step``: the serving pair; decode
donates the KV cache (in-place update at scale).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeSpec
from repro.models.api import Model
from repro.optim import adamw, clip_by_global_norm
from repro.parallel.sharding import (
    AxisRules,
    DEFAULT_RULES,
    shape_aware_shardings,
)
from repro.parallel import gradsync

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    grad_compression: str = "none"        # "none" | "int8"
    microbatches: int = 1


def state_axes(model: Model, settings: TrainSettings) -> Params:
    """Logical axes of the full TrainState (opt state mirrors params)."""
    p_axes = model.param_axes()
    st = {
        "params": p_axes,
        "opt": {"m": p_axes, "v": p_axes},
        "step": None,
    }
    if settings.grad_compression == "int8":
        st["residual"] = p_axes
    return st


def init_train_state(model: Model, settings: TrainSettings, key) -> Params:
    params = model.init(key)
    opt = adamw(settings.learning_rate, weight_decay=settings.weight_decay)
    st = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if settings.grad_compression == "int8":
        st["residual"] = gradsync.init_residual(params)
    return st


def train_state_spec(model: Model, settings: TrainSettings) -> Params:
    return jax.eval_shape(
        lambda k: init_train_state(model, settings, k), jax.random.PRNGKey(0))


def build_train_step(
    model: Model,
    mesh: Mesh,
    shape: ShapeSpec,
    settings: TrainSettings = TrainSettings(),
    rules: AxisRules = DEFAULT_RULES,
):
    """Returns (jitted step, state_shardings, batch_shardings, state_spec)."""
    opt = adamw(settings.learning_rate, weight_decay=settings.weight_decay)

    state_spec = train_state_spec(model, settings)
    st_shardings = shape_aware_shardings(
        state_spec, state_axes(model, settings), mesh, rules)
    batch_spec = model.input_specs(shape)
    batch_shardings = shape_aware_shardings(
        batch_spec, model.batch_axes(shape), mesh, rules)

    def step(state, batch):
        if settings.microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((settings.microbatches,
                                     x.shape[0] // settings.microbatches)
                                    + x.shape[1:]), batch)
            from repro.models.layers import scan_unroll_of
            loss, grads = gradsync.accumulate_grads(
                model.loss_fn, state["params"], mb,
                unroll=scan_unroll_of(model.cfg))
        else:
            loss, grads = jax.value_and_grad(model.loss_fn)(
                state["params"], batch)
        grads, gnorm = clip_by_global_norm(grads, settings.grad_clip)
        new_state = dict(state)
        if settings.grad_compression == "int8":
            grads, new_res = gradsync.compress_grads_ef(
                grads, state["residual"])
            new_state["residual"] = new_res
        params, opt_state = opt.update(grads, state["opt"], state["params"],
                                       state["step"])
        new_state["params"] = params
        new_state["opt"] = opt_state
        new_state["step"] = state["step"] + 1
        return new_state, {"loss": loss, "grad_norm": gnorm}

    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P())}
    jitted = jax.jit(
        step,
        in_shardings=(st_shardings, batch_shardings),
        out_shardings=(st_shardings, metrics_sh),
        donate_argnums=(0,),
    )
    return jitted, st_shardings, batch_shardings, state_spec


def build_fcnn_program_step(
    program,
    mesh: Mesh,
    settings: TrainSettings = TrainSettings(),
    kernel_mode: str | None = None,
):
    """Period-program analogue of ``build_train_step`` for the paper's
    FCNN: the loss is a compiled RUN/SEND/RECV/FREE schedule
    (exec.program.PeriodProgram) interpreted under shard_map on ``mesh``
    (exec.runtime), with the same AdamW + global-norm clipping as the
    generic step.  Returns (jitted step, executor); state is the plain
    {"params", "opt", "step"} dict (init via ``init_fcnn_program_state``).

    .. deprecated:: ISSUE 8 — thin shim over the façade
       (``repro.exec.Executable``), pinned to the replicated-residency
       oracle the old surface assumed.  New code should call
       ``repro.exec.compile(...)`` and ``Executable.train_step``.
    """
    from repro.deprecation import warn_deprecated
    from repro.exec.api import Executable

    warn_deprecated(
        "launch.steps.build_fcnn_program_step",
        "build_fcnn_program_step is deprecated; use repro.exec.compile(...)"
        " or Executable.from_program(...).train_step(...)")
    opt = adamw(settings.learning_rate, weight_decay=settings.weight_decay)
    exe = Executable.from_program(program, mesh, residency="replicated",
                                  kernel_mode=kernel_mode)
    step = exe.train_step(opt, grad_clip=settings.grad_clip)
    return step, exe.executor


def init_fcnn_program_state(program, settings: TrainSettings, key) -> Params:
    """TrainState for ``build_fcnn_program_step`` (params from the
    program's layer sizes, AdamW moments, step counter)."""
    from repro.models import fcnn

    params = fcnn.init(key, program.layer_sizes)
    opt = adamw(settings.learning_rate, weight_decay=settings.weight_decay)
    return {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.zeros((), jnp.int32),
    }


def _serving_specs(model: Model, mesh: Mesh, shape: ShapeSpec,
                   rules: AxisRules, max_len: int):
    p_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = shape_aware_shardings(p_spec, model.param_axes(), mesh, rules)
    kw = {}
    if model.cfg.family == "encdec":
        kw["enc_len"] = shape.seq_len // 2
    c_spec = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, max_len, **kw))
    c_sh = shape_aware_shardings(c_spec, model.cache_axes(), mesh, rules)
    return p_spec, p_sh, c_spec, c_sh


def _logits_sharding(model: Model, mesh: Mesh, shape: ShapeSpec,
                     rules: AxisRules):
    v = model.cfg.padded_vocab
    b_ax = rules.physical("activation_batch", mesh)
    v_ax = rules.physical("activation_vocab", mesh)
    from repro.parallel.sharding import _axis_size
    if v_ax is not None and v % _axis_size(mesh, v_ax) != 0:
        v_ax = None
    if b_ax is not None and shape.global_batch % _axis_size(mesh, b_ax) != 0:
        b_ax = None
    return NamedSharding(mesh, P(b_ax, None, v_ax))


def build_prefill_step(model: Model, mesh: Mesh, shape: ShapeSpec,
                       max_len: int | None = None,
                       rules: AxisRules = DEFAULT_RULES):
    max_len = max_len or shape.seq_len
    p_spec, p_sh, c_spec, c_sh = _serving_specs(model, mesh, shape, rules,
                                                max_len)
    batch_spec = model.input_specs(shape)
    b_sh = shape_aware_shardings(batch_spec, model.batch_axes(shape), mesh,
                                 rules)
    logits_sh = _logits_sharding(model, mesh, shape, rules)

    def fn(params, batch):
        return model.prefill(params, batch, max_len)

    jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                     out_shardings=(logits_sh, c_sh))
    return jitted, p_sh, b_sh, c_sh


def build_decode_step(model: Model, mesh: Mesh, shape: ShapeSpec,
                      rules: AxisRules = DEFAULT_RULES):
    decode_shape = ShapeSpec(shape.name, shape.seq_len, shape.global_batch,
                             "decode")
    p_spec, p_sh, c_spec, c_sh = _serving_specs(model, mesh, decode_shape,
                                                rules, shape.seq_len)
    batch_spec = model.input_specs(decode_shape)
    b_sh = shape_aware_shardings(
        batch_spec, model.batch_axes(decode_shape), mesh, rules)
    logits_sh = _logits_sharding(model, mesh, decode_shape, rules)

    def fn(params, cache, batch):
        return model.decode_step(params, cache, batch)

    jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                     out_shardings=(logits_sh, c_sh),
                     donate_argnums=(1,))
    return jitted, p_sh, b_sh, c_sh
