"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b-smoke \
      --steps 50 --batch 8 --seq 128

Works on CPU for smoke-size configs (the production path is the same code
under a real TPU mesh): builds the mesh from available devices, shards the
TrainState with the model's logical axes, runs the supervised train loop
with checkpoint/restart, straggler monitoring and (optional) int8 gradient
compression.
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.configs.base import ShapeSpec
from repro.checkpoint import Checkpointer
from repro.data import Batcher, token_stream
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_model
from repro.runtime import TrainingSupervisor

log = logging.getLogger(__name__)


def make_lm_data(cfg, n_tokens: int, batch: int, seq: int, mesh):
    stream = token_stream(n_tokens + 1, cfg.vocab_size, seed=0)
    n_seqs = n_tokens // seq
    toks = stream[: n_seqs * seq].reshape(n_seqs, seq)
    labels = stream[1 : n_seqs * seq + 1].reshape(n_seqs, seq)
    data = {"tokens": toks, "labels": labels}
    return Batcher(data, batch_size=batch, mesh=mesh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config for this arch")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", choices=["none", "int8"],
                    default="none")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "encdec"):
        raise SystemExit(
            "train.py drives token-LM archs; use examples/ for vlm/encdec")

    mesh = make_host_mesh()
    model = get_model(cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    settings = steps_lib.TrainSettings(
        learning_rate=args.lr, microbatches=args.microbatches,
        grad_compression=args.grad_compression)

    with mesh:
        step_fn, st_sh, b_sh, _ = steps_lib.build_train_step(
            model, mesh, shape, settings)
        state = steps_lib.init_train_state(model, settings,
                                           jax.random.PRNGKey(0))
        state = jax.device_put(state, st_sh)

        batches = make_lm_data(cfg, args.batch * args.seq * (args.steps + 4),
                               args.batch, args.seq, mesh)
        sup = TrainingSupervisor(
            Checkpointer(args.checkpoint_dir),
            checkpoint_every=args.checkpoint_every)

        def wrapped(state, batch):
            state, metrics = step_fn(state, batch)
            return state, {k: float(v) for k, v in metrics.items()}

        t0 = time.time()
        state, history = sup.run(state, wrapped, batches, args.steps,
                                 restore_shardings=st_sh)
        dt = time.time() - t0

    losses = [h["loss"] for h in history]
    print(f"\n{cfg.name}: {len(history)} steps in {dt:.1f}s "
          f"({dt / max(1, len(history)):.3f}s/step)")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f} "
          f"min={min(losses):.4f}")
    print(f"stragglers observed: {len(sup.straggler.straggler_steps)}")
    if losses[-1] >= losses[0]:
        raise SystemExit("loss did not decrease")


if __name__ == "__main__":
    main()
