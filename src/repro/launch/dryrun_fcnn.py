"""Dry-run the paper's own workload: FCNN (NN1-6) training steps on the
production mesh, with PER-LAYER sharding degrees chosen by the ONoC
planner (Lemma 1 snapped to mesh-feasible degrees) — the paper's technique
executing as real per-layer PartitionSpecs, not just as analysis.

  PYTHONPATH=src python -m repro.launch.dryrun_fcnn [--multipod] \
      [--out results/dryrun_fcnn.json]

Unlike the transformer stacks (uniform scanned layers), the FCNN's layers
are heterogeneous, so each layer really does get its own degree — layer 1
at min(n_1, φm), interior layers at interior optima, the 10-neuron output
layer at degree ≤ 10 (Eq. 10), exactly the structure of the paper's
Table 10.
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.nn_benchmarks import NN_BENCHMARKS, onoc_config, workload  # noqa: E402
from repro.core.planner import plan_fcnn  # noqa: E402
from repro.launch.dryrun import _metrics_of  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import fcnn  # noqa: E402
from repro.optim import adam  # noqa: E402


def lower_nn(name: str, batch: int, multi_pod: bool, lambda_max: int = 64,
             kernel_mode: str | None = None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    w = workload(name, batch)
    plan = plan_fcnn(w, onoc_config(lambda_max), dict(mesh.shape),
                     strategy="orrm")
    sizes = NN_BENCHMARKS[name]
    opt = adam(1e-3)

    # per-layer shardings from the plan's degrees
    def layer_sharding(i: int):
        axes = plan.periods[i].axes
        return {
            "w": NamedSharding(mesh, P(None, axes if axes else None)),
            "b": NamedSharding(mesh, P(axes if axes else None)),
        }

    p_sh = {"layers": [layer_sharding(i) for i in range(len(sizes) - 1)]}
    st_sh = {"params": p_sh, "opt": {"m": p_sh, "v": p_sh},
             "step": NamedSharding(mesh, P())}
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    b_sh = {"x": NamedSharding(mesh, P(data_axes, None)),
            "y": NamedSharding(mesh, P(data_axes))}

    def step(state, batch_):
        loss, grads = jax.value_and_grad(
            lambda p, b: fcnn.loss_fn(p, b, kernel_mode=kernel_mode)
        )(state["params"], batch_)
        params, opt_state = opt.update(grads, state["opt"], state["params"],
                                       state["step"])
        return ({"params": params, "opt": opt_state,
                 "step": state["step"] + 1}, loss)

    state_spec = jax.eval_shape(lambda k: {
        "params": fcnn.init(k, sizes),
        "opt": adam(1e-3).init(fcnn.init(k, sizes)),
        "step": jnp.zeros((), jnp.int32),
    }, jax.random.PRNGKey(0))
    batch_spec = {"x": jax.ShapeDtypeStruct((batch, sizes[0]), jnp.float32),
                  "y": jax.ShapeDtypeStruct((batch,), jnp.int32)}

    jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                     out_shardings=(st_sh, NamedSharding(mesh, P())),
                     donate_argnums=(0,))
    with mesh:
        lowered = jitted.lower(state_spec, batch_spec)
    return lowered, plan, mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--kernel", default=None,
                    choices=["ref", "pallas", "pallas_interpret"],
                    help="force the fcnn_layer dispatch mode")
    ap.add_argument("--out", default="results/dryrun_fcnn.json")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    mesh_name = "2x16x16" if args.multipod else "16x16"
    for name in sorted(NN_BENCHMARKS):
        key = f"{name}|train_b{args.batch}|{mesh_name}"
        print(f"[run] {key}", flush=True)
        t0 = time.time()
        try:
            lowered, plan, mesh = lower_nn(name, args.batch, args.multipod,
                                           kernel_mode=args.kernel)
            compiled = lowered.compile()
            m = _metrics_of(compiled)
            mem = compiled.memory_analysis()
            results[key] = {
                "ok": True,
                "degrees": plan.degrees,
                "onoc_cores": [p.onoc_cores for p in plan.periods],
                "flops_per_device": m["flops"],
                "collective_bytes": sum(v for k, v in m.items()
                                        if k.startswith("coll:")),
                "temp_gb": mem.temp_size_in_bytes / 1e9,
                "seconds": round(time.time() - t0, 1),
            }
            print(f"  ok: degrees={plan.degrees} "
                  f"(ONoC m*={[p.onoc_cores for p in plan.periods]}) "
                  f"[{results[key]['seconds']}s]")
        except Exception as e:  # noqa: BLE001
            results[key] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            print(f"  FAIL: {type(e).__name__}: {e}")
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"{n_ok}/{len(results)} FCNN cells ok -> {args.out}")


if __name__ == "__main__":
    main()
