"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries only data parallelism + cross-pod gradient reduction (DCN-ish
traffic), never tensor parallelism.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).
"""

from __future__ import annotations

import os

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh", "make_test_mesh"]

_HOST_COUNT_FLAG = "--xla_force_host_platform_device_count"


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, flat on the "data" axis (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_test_mesh(n: int = 8, axis: str = "cores") -> Mesh:
    """CPU multi-device ring for executor/shard_map tests — no TPUs needed.

    Forces ``n`` host CPU devices via XLA_FLAGS; only effective if jax has
    not initialized its backends yet, so set it as early as possible
    (tests/conftest.py forces 8 for the whole suite).  The first ``n``
    devices become a 1-axis ring mesh, the layout ``exec.runtime`` executes
    period programs on.
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if _HOST_COUNT_FLAG not in flags:
        # No-op if a backend already exists, harmless either way.
        os.environ["XLA_FLAGS"] = f"{_HOST_COUNT_FLAG}={n} {flags}".strip()
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)}; set "
            f"XLA_FLAGS={_HOST_COUNT_FLAG}={n} before the first jax call")
    return Mesh(np.asarray(devices[:n]), (axis,))
