"""Production mesh construction.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries only data parallelism + cross-pod gradient reduction (DCN-ish
traffic), never tensor parallelism.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax use).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Whatever devices exist, flat on the "data" axis (CPU tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
