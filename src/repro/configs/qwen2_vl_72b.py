"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf]

The vision frontend (ViT, dynamic resolution) is a STUB per the
assignment: input_specs provides precomputed patch/text embeddings plus a
(3, B, S) position tensor for M-RoPE (sections 16/24/24 over head_dim/2)."""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen2-vl-72b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        mrope_sections=(2, 3, 3),
        dtype="float32", param_dtype="float32", remat=False,
    )


register("qwen2-vl-72b", full, smoke)
