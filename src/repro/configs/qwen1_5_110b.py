"""qwen1.5-110b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064 — QKV bias.  [hf:Qwen/Qwen1.5-0.5B; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=49152, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen1.5-110b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=192, vocab_size=256, head_dim=16,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("qwen1.5-110b", full, smoke)
