"""granite-moe-1b-a400m [moe] — 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8.  [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-1b-a400m", family="moe",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=0, vocab_size=49155, head_dim=64,
        n_experts=32, experts_per_token=8, moe_d_ff=512,
        rope_theta=10_000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="granite-moe-1b-a400m-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, vocab_size=256, head_dim=16,
        n_experts=8, experts_per_token=2, moe_d_ff=32, moe_group_size=32,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("granite-moe-1b-a400m", full, smoke)
