"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality).  [arXiv:2405.21060; unverified]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm",
        n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
        ssm_groups=1, conv_kernel=4, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="mamba2-2.7b-smoke", n_layers=2, d_model=64, vocab_size=256,
        ssm_state=16, ssm_headdim=16, ssm_chunk=8,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("mamba2-2.7b", full, smoke)
