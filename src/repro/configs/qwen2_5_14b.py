"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab_size=152064, head_dim=128,
        qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen2.5-14b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("qwen2.5-14b", full, smoke)
