"""zamba2-1.2b [hybrid] — 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 + shared attn blocks.
[arXiv:2411.15242; hf]

long_500k runnable: the SSM carries unbounded context; the shared
attention block's KV cache is a 32k ring buffer (attn_window)."""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=32000,
        ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
        conv_kernel=4, shared_attn_every=6, attn_window=32_768,
        rope_theta=10_000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="zamba2-1.2b-smoke", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
        ssm_headdim=16, ssm_chunk=8, shared_attn_every=2, attn_window=64,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("zamba2-1.2b", full, smoke)
