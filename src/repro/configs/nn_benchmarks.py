"""The paper's FCNN benchmarks (Table 6) and evaluation grid (§5)."""

from repro.core.onoc_model import FCNNWorkload, ONoCConfig

NN_BENCHMARKS: dict[str, list[int]] = {
    "NN1": [784, 1000, 500, 10],
    "NN2": [784, 1500, 784, 1000, 500, 10],
    "NN3": [784, 2000, 1500, 784, 1000, 500, 10],
    "NN4": [784, 2500, 2000, 1500, 784, 1000, 500, 10],
    "NN5": [1024, 4000, 1000, 4000, 10],
    "NN6": [1024, 4000, 1000, 4000, 1000, 4000, 1000, 4000, 10],
}

BATCH_SIZES = (1, 8, 32, 64, 128)
WAVELENGTHS = (8, 64)
FNP_FIXED_CORES = 200                       # paper §5.3
ENOC_CORE_SWEEP = (40, 65, 90, 150, 250, 350)  # paper §5.4 / Fig. 10


def workload(name: str, batch_size: int = 1) -> FCNNWorkload:
    return FCNNWorkload(NN_BENCHMARKS[name], batch_size=batch_size)


def onoc_config(lambda_max: int = 64, m: int = 1000) -> ONoCConfig:
    return ONoCConfig(m=m, lambda_max=lambda_max)
