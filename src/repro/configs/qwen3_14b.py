"""qwen3-14b [dense] — 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab_size=151936, head_dim=128,
        qkv_bias=False, qk_norm=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("qwen3-14b", full, smoke)
