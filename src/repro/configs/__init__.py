"""Architecture registry.  Importing this package registers every assigned
architecture; ``get_config(name)`` / ``smoke_config(name)`` fetch them."""

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeSpec,
    SHAPES,
    get_config,
    list_archs,
    shape_cells,
    smoke_config,
)

# one import per assigned architecture — registration is a side effect
from repro.configs import (  # noqa: F401
    granite_3_2b,
    granite_moe_1b,
    mamba2_2_7b,
    qwen1_5_110b,
    qwen2_5_14b,
    qwen2_moe_a2_7b,
    qwen2_vl_72b,
    qwen3_14b,
    seamless_m4t_large_v2,
    zamba2_1_2b,
)
from repro.configs import nn_benchmarks  # noqa: F401
