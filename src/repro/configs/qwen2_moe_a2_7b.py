"""qwen2-moe-a2.7b [moe] — 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=0, vocab_size=151936, head_dim=128,
        n_experts=60, experts_per_token=4, moe_d_ff=1408,
        n_shared_experts=4, qkv_bias=True, rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen2-moe-a2.7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, vocab_size=256, head_dim=16,
        n_experts=6, experts_per_token=2, moe_d_ff=32, n_shared_experts=2,
        moe_group_size=32,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("qwen2-moe-a2.7b", full, smoke)
