"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16)
d_ff=8192 vocab=256206 — enc-dec, multimodal.  [arXiv:2308.11596; hf]

The audio frontend (conformer feature extractor) is a STUB per the
assignment: input_specs provides precomputed frame embeddings
(B, S_enc, d_model).  24 encoder + 24 decoder layers."""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec",
        n_layers=24, n_encoder_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=256206, head_dim=64,
        rope_theta=10_000.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="seamless-m4t-large-v2-smoke", n_layers=2, n_encoder_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        head_dim=16, dtype="float32", param_dtype="float32", remat=False,
    )


register("seamless-m4t-large-v2", full, smoke)
