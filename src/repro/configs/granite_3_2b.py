"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA.  [hf:ibm-granite/granite-3.0-2b-base; hf]"""

from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b", family="dense",
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
        d_ff=8192, vocab_size=49155, head_dim=64,
        qkv_bias=False, rope_theta=10_000.0, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="granite-3-2b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
        dtype="float32", param_dtype="float32", remat=False,
    )


register("granite-3-2b", full, smoke)
