"""Config dataclasses + the architecture registry.

One ``ModelConfig`` covers every assigned family; family-specific fields
default to "off".  Each architecture file in this package instantiates one
``ModelConfig`` (full size) and one ``smoke()`` reduction of the same
family for CPU tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "register", "get_config",
           "list_archs", "smoke_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim
    n_shared_experts: int = 0
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    moe_group_size: int = 512      # tokens per dispatch group
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_groups: int = 1
    conv_kernel: int = 4
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0     # apply the shared attention block every k layers
    # --- enc-dec (seamless) ---
    n_encoder_layers: int = 0
    # --- vlm (qwen2-vl) ---
    mrope_sections: tuple[int, ...] = ()
    # --- numerics / execution ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: bool = True
    # "full": nothing saveable (recompute everything in bwd);
    # "dots": save matmul outputs incl. post-collective tensors, so the
    #         backward recompute repeats no collectives
    remat_policy: str = "full"
    # matmul output dtype: "float32" (default) or "bfloat16" (bf16comm —
    # halves cross-shard partial-sum / backward-AR bytes; MXU still
    # accumulates f32 internally on TPU)
    accum_dtype: str = "float32"
    scan_layers: bool = True
    # dry-run cost probes: fully unroll every lax.scan so XLA's cost
    # analysis (which counts while-loop bodies exactly once) sees the true
    # totals.  Never set for production configs.
    probe_unroll: bool = False
    # long-context decode: cap attention window for hybrid archs (0 = full)
    attn_window: int = 0
    # switch to kv-chunked (flash-style) attention when Lq*Lk exceeds this
    attn_chunk_threshold: int = 4096 * 4096
    # fuse unembed+cross-entropy (never materialize (B, L, V) logits)
    fused_ce: bool = False
    # one-hot matmul embedding lookup (SPMD-friendly vs sharded gather)
    embed_onehot: bool = False

    # embedding tables are padded to a shardable multiple (standard
    # Megatron/MaxText practice); logits over padded slots train to -inf
    # and labels never index them.
    vocab_pad_multiple: int = 256

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only SSM/hybrid run it.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_cells(cfg: "ModelConfig") -> list[tuple[str, bool, str]]:
    """All four shape cells for an arch: (shape_name, runnable, reason)."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
            out.append((s.name, False, "full-attention arch: 500k KV cache "
                        "out of HBM budget; skip sanctioned by assignment"))
        else:
            out.append((s.name, True, ""))
    return out


_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _SMOKE[name] = smoke


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def smoke_config(name: str) -> ModelConfig:
    return _SMOKE[name]()


def list_archs() -> list[str]:
    return sorted(_REGISTRY)
