"""Blocked online-softmax (flash) attention for the prefill path.

Grid: (batch·heads, q_blocks, kv_blocks) with the kv dimension innermost
(sequential on TPU), carrying the running max/denominator/accumulator in
VMEM scratch across kv steps.  Causal blocks above the diagonal are skipped
with pl.when — for a full causal sweep that halves both the FLOPs and the
HBM traffic of the K/V stream.

VMEM budget per step: q (bq·D) + k,v (bkv·D each) + acc (bq·D) + m/l (bq)
in fp32 — for bq=bkv=512, D=128 that is ~1.3 MB, well inside the ~16 MB
VMEM of a v5e core with double-buffering headroom.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, kv_steps: int, block_q: int, block_kv: int, causal: bool,
            scale: float):
    qi = pl.program_id(1)
    kvi = pl.program_id(2)

    @pl.when(kvi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip blocks strictly above the diagonal
    run = (not causal) or (kvi * block_kv <= qi * block_q + block_q - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                   # (bq, D)
        k = k_ref[0]                                   # (bkv, D)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            cols = kvi * block_kv + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kvi == kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_kv", "interpret"))
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    causal: bool = True,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    bq, bkv = min(block_q, s), min(block_kv, s)
    if s % bq or s % bkv:
        raise ValueError(f"seq {s} not divisible by blocks ({bq},{bkv})")
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    grid = (bh, s // bq, s // bkv)
    out = pl.pallas_call(
        functools.partial(
            _kernel, kv_steps=grid[2], block_q=bq, block_kv=bkv,
            causal=causal, scale=1.0 / math.sqrt(d)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda g, i, j: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
