"""Fused FCNN period kernels: forward act(x @ w + b) plus the matching
backward (dgrad / wgrad) passes, MXU-aligned and VMEM-tiled.

This is the paper's per-period hot loop (Eq. 1) and its BP transpose
(Eqs. 2-3).  On the ONoC each core computes X_i neurons over the batch; on
TPU one chip computes its neuron shard as a blocked GEMM.  Fusing the
element-wise work next to the GEMM removes HBM round-trips of (M, N)
tensors — with batch 128 and n_i = 4000 (NN5/NN6) that's 2 MB per period
per chip per tensor saved at ~819 GB/s:

  * forward  — bias add + activation fused into the x @ w epilogue;
  * dgrad    — dZ = dY ⊙ A'(Y) fused into the dZ @ Wᵀ prologue, so the
               pre-activation gradient never exists in HBM;
  * wgrad    — dW = Xᵀ @ dZ and the db column-reduce in one pass, with the
               same fused dZ recompute (an element-wise flop traded for an
               (M, N) HBM read+write, the flash-attention discipline).

All activation derivatives are expressed in terms of the *output* Y, so the
backward needs only (x, w, y) as tensor residuals — no pre-activation Z is
ever saved (the (N,) bias also rides along, solely to dtype the db
cotangent):

  sigmoid': y (1 - y)     relu': 1[y > 0]     tanh': 1 - y²     none: 1

Blocking: grids put the contraction dimension innermost (sequential on
TPU) with an fp32 accumulator in VMEM scratch.  Block sizes are chosen
automatically (``_select_block``): sublane-unit 8 for M, lane-unit 128 for
K/N, minimizing edge padding.  Non-aligned shapes — the paper's 784/10/…
NN benchmark dims — are zero-padded to block multiples and the result is
sliced back; zero padding is exact for all three passes (padded rows /
columns contribute 0 to every contraction and are discarded on output).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import act_deriv_from_output

__all__ = [
    "fcnn_layer",
    "fcnn_layer_dgrad",
    "fcnn_layer_wgrad",
    "select_blocks",
]

_ACTS = {
    "sigmoid": jax.nn.sigmoid,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "none": lambda z: z,
}

# Default preferred block sizes (MXU-aligned); the contraction block is
# larger to amortize accumulator revisits.
_DEFAULT_BLOCK_M = 128
_DEFAULT_BLOCK_N = 128
_DEFAULT_BLOCK_K = 512

_SUBLANE = 8    # fp32 sublane unit (second-to-last dim)
_LANE = 128     # lane unit (last dim)


def _round_up(v: int, unit: int) -> int:
    return -(-v // unit) * unit


def _select_block(dim: int, preferred: int | None, default: int,
                  unit: int) -> tuple[int, int]:
    """Pick (block, padded_dim) for one dimension.

    The block is a multiple of ``unit``, at most the preferred size (clamped
    to the dim rounded up to ``unit``), chosen to minimize edge padding —
    ties go to the largest block (fewer grid steps).
    """
    pref = preferred if preferred is not None else default
    pref = min(_round_up(max(pref, unit), unit), _round_up(dim, unit))
    best_b, best_pad = pref, _round_up(dim, pref)
    b = pref - unit
    while b >= unit:
        pad = _round_up(dim, b)
        if pad < best_pad:
            best_b, best_pad = b, pad
        b -= unit
    return best_b, best_pad


def select_blocks(
    m: int, k: int, n: int,
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
) -> tuple[tuple[int, int, int], tuple[int, int, int]]:
    """((bm, bn, bk), (m_pad, n_pad, k_pad)) for an (M, K) x (K, N) problem."""
    bm, m_pad = _select_block(m, block_m, _DEFAULT_BLOCK_M, _SUBLANE)
    bn, n_pad = _select_block(n, block_n, _DEFAULT_BLOCK_N, _LANE)
    bk, k_pad = _select_block(k, block_k, _DEFAULT_BLOCK_K, _LANE)
    return (bm, bn, bk), (m_pad, n_pad, k_pad)


def _pad2(x: jax.Array, rows: int, cols: int) -> jax.Array:
    r, c = x.shape
    if r == rows and c == cols:
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


def _pad1(x: jax.Array, size: int) -> jax.Array:
    (s,) = x.shape
    return x if s == size else jnp.pad(x, (0, size - s))


# ---------------------------------------------------------------- forward


def _fwd_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                act: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        z = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _ACTS[act](z).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "interpret"),
)
def fcnn_layer(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "sigmoid",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """act(x @ w + b).  x: (M, K); w: (K, N); b: (N,)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    if activation not in _ACTS:
        raise ValueError(f"unknown activation {activation!r}")
    (bm, bn, bk), (mp, np_, kp) = select_blocks(
        m, k, n, block_m, block_n, block_k)
    xp, wp, bp = _pad2(x, mp, kp), _pad2(w, kp, np_), _pad1(b, np_)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, k_steps=grid[2], act=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return out[:m, :n]


# ------------------------------------------------------------------ dgrad


def _dgrad_kernel(dy_ref, y_ref, w_ref, dx_ref, acc_ref, *, n_steps: int,
                  act: str):
    """dX block += (dY ⊙ A'(Y)) @ Wᵀ — activation derivative fused into the
    GEMM prologue so dZ never touches HBM."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    y = y_ref[...].astype(jnp.float32)
    dz = dy_ref[...].astype(jnp.float32) * act_deriv_from_output(y, act)
    # (bm, bn) x (bk, bn) contracted on bn -> (bm, bk)   (== dz @ w_blk.T)
    acc_ref[...] += jax.lax.dot_general(
        dz, w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == n_steps - 1)
    def _finish():
        dx_ref[...] = acc_ref[...].astype(dx_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "interpret"),
)
def fcnn_layer_dgrad(
    dy: jax.Array,
    y: jax.Array,
    w: jax.Array,
    activation: str = "sigmoid",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """dX = (dY ⊙ A'(Y)) @ Wᵀ.  dy, y: (M, N); w: (K, N); returns (M, K)."""
    m, n = dy.shape
    k, n2 = w.shape
    assert y.shape == (m, n) and n == n2
    (bm, bn, bk), (mp, np_, kp) = select_blocks(
        m, k, n, block_m, block_n, block_k)
    dyp, yp, wp = _pad2(dy, mp, np_), _pad2(y, mp, np_), _pad2(w, kp, np_)
    grid = (mp // bm, kp // bk, np_ // bn)   # N innermost: accumulate
    out = pl.pallas_call(
        functools.partial(_dgrad_kernel, n_steps=grid[2], act=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, nn: (i, nn)),
            pl.BlockSpec((bm, bn), lambda i, j, nn: (i, nn)),
            pl.BlockSpec((bk, bn), lambda i, j, nn: (j, nn)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j, nn: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, kp), dy.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(dyp, yp, wp)
    return out[:m, :k]


# ------------------------------------------------------------------ wgrad


def _wgrad_kernel(x_ref, dy_ref, y_ref, dw_ref, db_ref, accw_ref, accb_ref,
                  *, m_steps: int, act: str):
    """dW block += Xᵀ @ (dY ⊙ A'(Y));  db block += column-reduce of dZ.

    Grid is (N, K, M) with M innermost.  The db output block depends only
    on the N index, so its VMEM buffer persists across the whole (K, M)
    inner sweep — db work is done only on the K==0 slice to avoid double
    counting, and the buffer is flushed once when N advances.
    """
    j_k = pl.program_id(1)
    j_m = pl.program_id(2)

    @pl.when(j_m == 0)
    def _init_w():
        accw_ref[...] = jnp.zeros_like(accw_ref)

    @pl.when((j_m == 0) & (j_k == 0))
    def _init_b():
        accb_ref[...] = jnp.zeros_like(accb_ref)

    y = y_ref[...].astype(jnp.float32)
    dz = dy_ref[...].astype(jnp.float32) * act_deriv_from_output(y, act)
    # (bm, bk) x (bm, bn) contracted on bm -> (bk, bn)   (== x_blk.T @ dz)
    accw_ref[...] += jax.lax.dot_general(
        x_ref[...], dz,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j_k == 0)
    def _acc_b():
        accb_ref[...] += jnp.sum(dz, axis=0)

    @pl.when(j_m == m_steps - 1)
    def _finish_w():
        dw_ref[...] = accw_ref[...].astype(dw_ref.dtype)

    @pl.when((j_m == m_steps - 1) & (j_k == 0))
    def _finish_b():
        db_ref[...] = accb_ref[...].astype(db_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k",
                     "interpret"),
)
def fcnn_layer_wgrad(
    x: jax.Array,
    dy: jax.Array,
    y: jax.Array,
    activation: str = "sigmoid",
    block_m: int | None = None,
    block_n: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """(dW, db) = (Xᵀ @ dZ, Σ_rows dZ) with dZ = dY ⊙ A'(Y) recomputed
    in-kernel.  x: (M, K); dy, y: (M, N); returns ((K, N), (N,))."""
    m, k = x.shape
    m2, n = dy.shape
    assert m == m2 and y.shape == (m, n)
    (bm, bn, bk), (mp, np_, kp) = select_blocks(
        m, k, n, block_m, block_n, block_k)
    xp, dyp, yp = _pad2(x, mp, kp), _pad2(dy, mp, np_), _pad2(y, mp, np_)
    grid = (np_ // bn, kp // bk, mp // bm)   # M innermost: accumulate
    dw, db = pl.pallas_call(
        functools.partial(_wgrad_kernel, m_steps=grid[2], act=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda jn, jk, jm: (jm, jk)),
            pl.BlockSpec((bm, bn), lambda jn, jk, jm: (jm, jn)),
            pl.BlockSpec((bm, bn), lambda jn, jk, jm: (jm, jn)),
        ],
        out_specs=[
            pl.BlockSpec((bk, bn), lambda jn, jk, jm: (jk, jn)),
            pl.BlockSpec((bn,), lambda jn, jk, jm: (jn,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((kp, np_), x.dtype),
            jax.ShapeDtypeStruct((np_,), dy.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bk, bn), jnp.float32),
            pltpu.VMEM((bn,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, dyp, yp)
    return dw[:k, :n], db[:n]
