"""Fused FCNN period kernel: act(x @ w + b) with MXU-aligned VMEM tiling.

This is the paper's per-period hot loop (Eq. 1).  On the ONoC each core
computes X_i neurons over the batch; on TPU one chip computes its neuron
shard as a blocked GEMM.  Fusing bias+activation removes one HBM round-trip
of the (M, N) activation tensor — with batch 128 and n_i = 4000 (NN5/NN6)
that's 2 MB per period per chip saved at ~819 GB/s.

Blocking: grid (M/bm, N/bn, K/bk), K innermost (sequential on TPU), fp32
accumulator in VMEM scratch; block shapes default to 128/MXU-aligned and
are clamped to the problem size.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fcnn_layer"]

_ACTS = {
    "sigmoid": jax.nn.sigmoid,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "none": lambda z: z,
}


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps: int, act: str):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        z = acc_ref[...] + b_ref[...].astype(jnp.float32)
        o_ref[...] = _ACTS[act](z).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("activation", "block_m", "block_n", "block_k", "interpret"),
)
def fcnn_layer(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    activation: str = "sigmoid",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """act(x @ w + b).  x: (M, K); w: (K, N); b: (N,)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    if m % bm or n % bn or k % bk:
        raise ValueError(
            f"shape ({m},{k})x({k},{n}) not divisible by blocks ({bm},{bn},{bk})"
        )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=grid[2], act=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w, b)
