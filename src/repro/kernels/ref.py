"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against
(tests/test_kernels.py sweeps shapes/dtypes and asserts allclose), and the
CPU execution path of ops.py.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "act_deriv_from_output",
    "fcnn_layer_ref",
    "fcnn_layer_dgrad_ref",
    "fcnn_layer_wgrad_ref",
    "softmax_xent_ref",
    "softmax_xent_dlogits_ref",
    "flash_attention_ref",
    "ssd_chunk_ref",
]


def fcnn_layer_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                   activation: str = "sigmoid") -> jax.Array:
    """One FCNN period: act(x @ w + b).  x: (M, K), w: (K, N), b: (N,)."""
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "sigmoid":
        z = jax.nn.sigmoid(z)
    elif activation == "relu":
        z = jax.nn.relu(z)
    elif activation == "tanh":
        z = jnp.tanh(z)
    elif activation == "none":
        pass
    else:
        raise ValueError(f"unknown activation {activation!r}")
    return z.astype(x.dtype)


def act_deriv_from_output(y: jax.Array, activation: str) -> jax.Array:
    """A'(z) expressed via the activation OUTPUT y (fp32 in, fp32 out).

    Shared by the oracles below AND the fused Pallas dgrad/wgrad kernels
    (pure jnp, so it traces inside a kernel body) — one table, so a new
    activation cannot silently diverge between kernel and ground truth.
    """
    if activation == "sigmoid":
        return y * (1.0 - y)
    if activation == "relu":
        return (y > 0).astype(jnp.float32)
    if activation == "tanh":
        return 1.0 - y * y
    if activation == "none":
        return jnp.ones_like(y)
    raise ValueError(f"unknown activation {activation!r}")


def _dz(dy: jax.Array, y: jax.Array, activation: str) -> jax.Array:
    return dy.astype(jnp.float32) * act_deriv_from_output(
        y.astype(jnp.float32), activation)


def fcnn_layer_dgrad_ref(dy: jax.Array, y: jax.Array, w: jax.Array,
                         activation: str = "sigmoid") -> jax.Array:
    """dX = (dY ⊙ A'(Y)) @ Wᵀ — oracle for the fused dgrad kernel."""
    dz = _dz(dy, y, activation)
    dx = jnp.dot(dz, w.astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)
    return dx.astype(dy.dtype)


def fcnn_layer_wgrad_ref(x: jax.Array, dy: jax.Array, y: jax.Array,
                         activation: str = "sigmoid"):
    """(dW, db) = (Xᵀ @ dZ, Σ_rows dZ) — oracle for the fused wgrad kernel."""
    dz = _dz(dy, y, activation)
    dw = jnp.dot(x.astype(jnp.float32).T, dz,
                 preferred_element_type=jnp.float32)
    db = jnp.sum(dz, axis=0)
    return dw.astype(x.dtype), db.astype(dy.dtype)


def softmax_xent_ref(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy (the paper's output period, §5.1).

    logits: (B, C); labels: (B,) int.  fp32 scalar — bit-identical to the
    pre-fusion jnp loss this kernel replaced in models/fcnn.loss_fn.
    """
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def softmax_xent_dlogits_ref(logits: jax.Array, labels: jax.Array,
                             g: jax.Array) -> jax.Array:
    """dlogits = (softmax − onehot) · g/B — oracle for the fused backward
    of the mean cross-entropy (g is the scalar loss cotangent)."""
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return ((p - onehot) * (g / logits.shape[0])).astype(logits.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D), softmax in fp32."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    if causal:
        sq, sk = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((sq, sk), dtype=bool), k=sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def ssd_chunk_ref(x: jax.Array, dt_a: jax.Array, b: jax.Array, c: jax.Array):
    """Intra-chunk SSD for ONE chunk (the Pallas kernel's unit of work).

    x: (Q, H, P); dt_a: (Q, H); b, c: (Q, H, N) (groups pre-broadcast).
    Returns (y_diag (Q, H, P), chunk_state (H, P, N), decay_out (Q, H)):
      y_diag[t]    = sum_{s<=t} C_t·B_s exp(sum_{s<k<=t} dtA_k) x_s
      chunk_state  = sum_s exp(sum_{s<k<=Q} dtA_k) B_s x_s^T
      decay_out[t] = exp(sum_{k<=t} dtA_k)   (for the inter-chunk readout)
    """
    q = x.shape[0]
    a = dt_a.astype(jnp.float32)
    cs = jnp.cumsum(a, axis=0)                                 # (Q, H)
    seg = cs[:, None, :] - cs[None, :, :]                      # (Q, Q, H)
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    lmat = jnp.where(mask[..., None], jnp.exp(seg), 0.0)       # (Q, Q, H)
    scores = jnp.einsum("thn,shn->tsh", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    y = jnp.einsum("tsh,tsh,shp->thp", scores, lmat,
                   x.astype(jnp.float32))
    decay_state = jnp.exp(cs[-1][None, :] - cs)                # (Q, H)
    state = jnp.einsum("shn,sh,shp->hpn", b.astype(jnp.float32),
                       decay_state, x.astype(jnp.float32))
    decay_out = jnp.exp(cs)
    return y.astype(x.dtype), state, decay_out
