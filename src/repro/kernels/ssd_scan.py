"""Mamba2 SSD intra-chunk kernel.

One grid step computes, for a single (batch·chunk, head-block) pair, the
quadratic intra-chunk term, the chunk's contribution to the running state,
and the output decay vector (consumed by the inter-chunk jnp scan, which is
O(n_chunks) and stays outside the kernel):

  y_diag[t] = Σ_{s<=t} (C_t·B_s) exp(Σ_{s<k<=t} dtA_k) x_s
  state     = Σ_s exp(Σ_{s<k<=Q} dtA_k) B_s x_sᵀ
  decay_out = exp(cumsum(dtA))

The (Q, Q) decay matrix is built in-register from a cumulative sum — this
is the part a TPU wants fused: materializing L to HBM at (B, H, C, Q, Q)
fp32 is Q/(2·P)× the size of the input itself (Q=128, P=64 ⇒ 1×), and the
fusion removes it entirely.

Head-blocking: heads are independent; block_h heads per step keeps the
(Q, Q, bh) decay tensor inside VMEM (128·128·8·4B = 512 KB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_chunk"]


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, dec_ref):
    # shapes: x (1, Q, bh, P); a (1, Q, bh); b/c (1, Q, bh, N)
    x = x_ref[0].astype(jnp.float32)
    a = a_ref[0].astype(jnp.float32)
    bb = b_ref[0].astype(jnp.float32)
    cc = c_ref[0].astype(jnp.float32)
    q = x.shape[0]

    cs = jnp.cumsum(a, axis=0)                          # (Q, bh)
    seg = cs[:, None, :] - cs[None, :, :]               # (Q, Q, bh)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = rows >= cols
    lmat = jnp.where(tri[..., None], jnp.exp(seg), 0.0)  # (Q, Q, bh)

    scores = jnp.einsum("thn,shn->tsh", cc, bb)          # (Q, Q, bh)
    y = jnp.einsum("tsh,shp->thp", scores * lmat, x)     # (Q, bh, P)

    decay_state = jnp.exp(cs[-1][None, :] - cs)          # (Q, bh)
    st = jnp.einsum("shn,sh,shp->hpn", bb, decay_state, x)

    y_ref[0] = y.astype(y_ref.dtype)
    st_ref[0] = st
    dec_ref[0] = jnp.exp(cs)


@functools.partial(jax.jit, static_argnames=("block_h", "interpret"))
def ssd_chunk(
    x: jax.Array,      # (BC, Q, H, P)  batch·chunks flattened
    dt_a: jax.Array,   # (BC, Q, H)
    b: jax.Array,      # (BC, Q, H, N)  groups pre-broadcast to heads
    c: jax.Array,      # (BC, Q, H, N)
    block_h: int = 8,
    interpret: bool = False,
):
    """Returns (y_diag (BC,Q,H,P), state (BC,H,P,N), decay_out (BC,Q,H))."""
    bc, q, h, p = x.shape
    n = b.shape[-1]
    bh = min(block_h, h)
    if h % bh:
        raise ValueError(f"heads {h} not divisible by block_h {bh}")
    grid = (bc, h // bh)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, bh, p), lambda g, hh: (g, 0, hh, 0)),
            pl.BlockSpec((1, q, bh), lambda g, hh: (g, 0, hh)),
            pl.BlockSpec((1, q, bh, n), lambda g, hh: (g, 0, hh, 0)),
            pl.BlockSpec((1, q, bh, n), lambda g, hh: (g, 0, hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, bh, p), lambda g, hh: (g, 0, hh, 0)),
            pl.BlockSpec((1, bh, p, n), lambda g, hh: (g, hh, 0, 0)),
            pl.BlockSpec((1, q, bh), lambda g, hh: (g, 0, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bc, q, h, p), x.dtype),
            jax.ShapeDtypeStruct((bc, h, p, n), jnp.float32),
            jax.ShapeDtypeStruct((bc, q, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt_a, b, c)
