"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel ships with a pure-jnp oracle (ref.py) and a jit'd public
wrapper (ops.py) that falls back to the oracle off-TPU.
"""

from repro.kernels.ops import (  # noqa: F401
    fcnn_layer,
    flash_attention,
    softmax_xent,
    ssd_chunk,
)
