"""Fused softmax + cross-entropy kernels for the FCNN output period.

The paper's output layer (§5.1) is softmax + cross-entropy over n_l = 10
classes.  Unfused, the loss round-trips the full (B, n_l) logits tensor
through HBM three times (logits read for log-softmax, log-probs written,
log-probs read again for the NLL gather — and the same again for dlogits
in the backward).  These kernels keep everything per-row in VMEM:

  * forward  — one streaming sweep over class tiles per row block,
               carrying the running max m and rescaled exp-sum l in VMEM
               scratch (the flash-attention online-softmax recurrence),
               plus the picked target logit t; the final tile emits
               nll = (m + log l) − t and the log-sum-exp per row.  Neither
               probabilities nor log-probs ever exist in HBM — only the
               two (B,) vectors (nll, lse) come back.
  * backward — dlogits = (softmax − onehot) · scale computed directly from
               the saved (B,) lse residual: p = exp(x − lse), one read of
               the logits and one write of dlogits, nothing else.

Blocking/padding follows the fcnn_layer rules exactly (shared helpers):
blocks auto-selected with sublane unit 8 for the batch dim and lane unit
128 for the class dim, minimizing edge padding; non-aligned shapes — the
paper's n_l = 10 output layers, batch 1 eval rows — are zero-padded to
block multiples and sliced back, so callers never pad.  Padded class
columns are masked to −1e30 inside the forward kernel (a zero-padded
column would otherwise contribute exp(0) to every row's denominator);
padded rows compute garbage that is sliced away.

VMEM per step: one (bb, bc) logits tile + three (bb,) fp32 carries —
for bb=128, bc=512 that is ~260 KB, far inside a v5e core's ~16 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.fcnn_layer import (
    _LANE,
    _SUBLANE,
    _pad1,
    _pad2,
    _select_block,
)

__all__ = ["softmax_xent_fwd", "softmax_xent_dlogits", "select_blocks_xent"]

# Preferred blocks for a (B, C) problem: batch rows on the sublane axis,
# class columns on the lane axis (larger, to amortize the carry revisits).
_DEFAULT_BLOCK_B = 128
_DEFAULT_BLOCK_C = 512

_NEG_INF = -1e30


def select_blocks_xent(
    b: int, c: int,
    block_b: int | None = None,
    block_c: int | None = None,
) -> tuple[tuple[int, int], tuple[int, int]]:
    """((bb, bc), (b_pad, c_pad)) for a (B, C) logits tensor — same
    minimize-edge-padding rule as ``fcnn_layer.select_blocks``."""
    bb, b_pad = _select_block(b, block_b, _DEFAULT_BLOCK_B, _SUBLANE)
    bc, c_pad = _select_block(c, block_c, _DEFAULT_BLOCK_C, _LANE)
    return (bb, bc), (b_pad, c_pad)


# ---------------------------------------------------------------- forward


def _fwd_kernel(x_ref, lab_ref, nll_ref, lse_ref, m_ref, l_ref, t_ref,
                *, c_steps: int, n_classes: int):
    """Online softmax over class tiles: carry (m, l, t) per row in VMEM."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        t_ref[...] = jnp.zeros_like(t_ref)

    x = x_ref[...].astype(jnp.float32)
    bc = x.shape[1]
    cols = j * bc + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    # padded class columns must not feed the max/denominator
    x = jnp.where(cols < n_classes, x, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(jnp.exp(x - m_new[:, None]),
                                              axis=-1)
    m_ref[...] = m_new
    # the label's logit lives in exactly one tile per row
    t_ref[...] += jnp.sum(
        jnp.where(cols == lab_ref[...][:, None], x, 0.0), axis=-1)

    @pl.when(j == c_steps - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(l_ref[...])
        lse_ref[...] = lse
        nll_ref[...] = lse - t_ref[...]


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_c", "interpret"))
def softmax_xent_fwd(
    logits: jax.Array,
    labels: jax.Array,
    block_b: int | None = None,
    block_c: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Per-row cross-entropy.  logits: (B, C); labels: (B,) int.

    Returns (nll, lse), both (B,) fp32: nll[r] = lse[r] − logits[r, y_r]
    with lse the log-sum-exp — the only residual the backward needs.
    """
    b, c = logits.shape
    assert labels.shape == (b,)
    (bb, bc), (bp, cp) = select_blocks_xent(b, c, block_b, block_c)
    xp = _pad2(logits, bp, cp)
    labp = _pad1(labels, bp)
    grid = (bp // bb, cp // bc)   # class tiles innermost: sequential carry
    nll, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, c_steps=grid[1], n_classes=c),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.float32),
            jax.ShapeDtypeStruct((bp,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
            pltpu.VMEM((bb,), jnp.float32),
        ],
        interpret=interpret,
    )(xp, labp)
    return nll[:b], lse[:b]


# --------------------------------------------------------------- backward


def _bwd_kernel(x_ref, lab_ref, lse_ref, scale_ref, dx_ref):
    """dX tile = (exp(x − lse) − onehot) · scale — softmax recomputed from
    the (B,) lse residual, so probabilities never existed in HBM."""
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)
    bc = x.shape[1]
    cols = j * bc + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    p = jnp.exp(x - lse_ref[...][:, None])
    onehot = (cols == lab_ref[...][:, None]).astype(jnp.float32)
    dx_ref[...] = ((p - onehot) * scale_ref[...][:, None]).astype(
        dx_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_c", "interpret"))
def softmax_xent_dlogits(
    logits: jax.Array,
    labels: jax.Array,
    lse: jax.Array,
    scale: jax.Array,
    block_b: int | None = None,
    block_c: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """dlogits = (softmax(logits) − onehot(labels)) · scale[:, None].

    logits: (B, C); labels, lse, scale: (B,).  ``scale`` carries the loss
    cotangent divided by the batch size (mean reduction), so the kernel
    writes the finished gradient in one pass.
    """
    b, c = logits.shape
    assert labels.shape == (b,) and lse.shape == (b,) and scale.shape == (b,)
    (bb, bc), (bp, cp) = select_blocks_xent(b, c, block_b, block_c)
    xp = _pad2(logits, bp, cp)
    labp = _pad1(labels, bp)
    lsep = _pad1(lse, bp)
    scalep = _pad1(scale, bp)
    grid = (bp // bb, cp // bc)   # independent tiles, no carry
    out = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
            pl.BlockSpec((bb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((bb, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, cp), logits.dtype),
        interpret=interpret,
    )(xp, labp, lsep, scalep)
    return out[:b, :c]
