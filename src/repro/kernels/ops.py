"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy
---------------
Every wrapper resolves a *mode* per call:

  * ``force=None`` (default) — ``"pallas"`` on a TPU backend (compiled
    kernels), ``"ref"`` anywhere else (the pure-jnp oracles from ref.py,
    bit-compatible semantics).  Models and launch code therefore call these
    unconditionally; CPU tests and lowering-only dry-runs transparently get
    the oracle path.
  * ``force="ref"`` — the oracle, always.  Differentiable by ordinary JAX
    autodiff; this is the ground truth the kernels are validated against.
  * ``force="pallas"`` — the compiled TPU kernel regardless of backend
    (will fail off-TPU; used by hardware benchmarks).
  * ``force="pallas_interpret"`` — the Pallas kernels in interpreter mode:
    same kernel code, runs on CPU.  Used by tests/test_kernels.py to
    validate both values and gradients without hardware.

fcnn_layer: fused forward AND backward
--------------------------------------
``fcnn_layer`` is the production hot path of the paper's per-period FCNN
loop, so its Pallas modes carry a ``jax.custom_vjp``: the forward saves
(x, w, b, y) — b only to dtype the db cotangent, never a pre-activation
Z — and the backward runs two fused kernels —

  * dgrad: dX = (dY ⊙ A'(Y)) @ Wᵀ, activation derivative fused into the
    GEMM prologue (the pre-activation gradient dZ never reaches HBM);
  * wgrad: dW = Xᵀ @ dZ and db = Σ_rows dZ in one pass, recomputing the
    cheap element-wise dZ instead of materializing it.

so ``jax.grad`` through a Pallas-dispatched ``fcnn_layer`` stays fused end
to end, while ``force="ref"`` keeps plain autodiff of the oracle.  Both
paths agree to fp32 tolerance (see tests/test_kernels.py).

softmax_xent: the fused output period
-------------------------------------
``softmax_xent`` closes the loop on the 2l-period pipeline: the loss
itself.  Its Pallas modes also carry a ``jax.custom_vjp`` —

  * forward: one online-softmax sweep over class tiles returning per-row
    (nll, lse); the loss is the mean of nll, and lse is the ONLY tensor
    residual beyond the primals (two (B,) vectors — probabilities and
    log-probs never reach HBM);
  * backward: dlogits = (softmax − onehot) · ḡ/B recomputed from lse in
    a single fused pass.

Labels are integer class ids and get a ``None`` cotangent.

Block sizes & padding: kernels auto-select MXU-aligned blocks and
zero-pad edge tiles, so non-128-divisible shapes (784, 10, …) are
accepted in every mode; explicit ``block_m/n/k`` overrides act as
preferred sizes rather than hard divisibility requirements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fcnn_layer import (
    fcnn_layer as _fcnn_pallas,
    fcnn_layer_dgrad as _fcnn_dgrad_pallas,
    fcnn_layer_wgrad as _fcnn_wgrad_pallas,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.softmax_xent import (
    softmax_xent_dlogits as _xent_dlogits_pallas,
    softmax_xent_fwd as _xent_fwd_pallas,
)
from repro.kernels.ssd_scan import ssd_chunk as _ssd_pallas

__all__ = ["fcnn_layer", "softmax_xent", "flash_attention", "ssd_chunk",
           "resolve_mode"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_mode(force: str | None) -> str:
    """Resolve the dispatch mode every wrapper below uses: ``force`` if
    given, else "pallas" on TPU and "ref" elsewhere.  Public so long-lived
    callers (the period-program executor, benchmark harnesses) can freeze
    one mode up front instead of re-resolving per call."""
    if force is not None:
        if force not in ("ref", "pallas", "pallas_interpret"):
            raise ValueError(f"unknown kernel mode {force!r}")
        return force
    return "pallas" if _on_tpu() else "ref"


_mode = resolve_mode


@functools.lru_cache(maxsize=None)
def _fused_fcnn(activation: str, interpret: bool, blocks: tuple):
    """custom_vjp-wrapped fused forward/backward for one static config."""
    bl = dict(blocks)

    @jax.custom_vjp
    def f(x, w, b):
        return _fcnn_pallas(x, w, b, activation, interpret=interpret, **bl)

    def fwd(x, w, b):
        y = _fcnn_pallas(x, w, b, activation, interpret=interpret, **bl)
        return y, (x, w, b, y)

    def bwd(res, dy):
        x, w, b, y = res
        dx = _fcnn_dgrad_pallas(dy, y, w, activation,
                                interpret=interpret, **bl)
        dw, db = _fcnn_wgrad_pallas(x, dy, y, activation,
                                    interpret=interpret, **bl)
        return (dx.astype(x.dtype), dw.astype(w.dtype), db.astype(b.dtype))

    f.defvjp(fwd, bwd)
    return f


def fcnn_layer(x, w, b, activation: str = "sigmoid", *,
               force: str | None = None, **blocks):
    mode = _mode(force)
    if mode == "ref":
        return _ref.fcnn_layer_ref(x, w, b, activation)
    interp = mode == "pallas_interpret"
    fused = _fused_fcnn(activation, interp, tuple(sorted(blocks.items())))
    return fused(x, w, b)


@functools.lru_cache(maxsize=None)
def _fused_xent(interpret: bool, blocks: tuple):
    """custom_vjp-wrapped fused softmax/cross-entropy for one config."""
    bl = dict(blocks)

    @jax.custom_vjp
    def f(logits, labels):
        nll, _ = _xent_fwd_pallas(logits, labels, interpret=interpret, **bl)
        return jnp.mean(nll)

    def fwd(logits, labels):
        nll, lse = _xent_fwd_pallas(logits, labels, interpret=interpret,
                                    **bl)
        return jnp.mean(nll), (logits, labels, lse)

    def bwd(res, g):
        logits, labels, lse = res
        # fold the mean's 1/B and the loss cotangent into one per-row scale
        scale = jnp.full((logits.shape[0],), g / logits.shape[0],
                         jnp.float32)
        dl = _xent_dlogits_pallas(logits, labels, lse, scale,
                                  interpret=interpret, **bl)
        return dl.astype(logits.dtype), None   # labels: integer, no grad

    f.defvjp(fwd, bwd)
    return f


def softmax_xent(logits, labels, *, force: str | None = None, **blocks):
    """Mean softmax cross-entropy loss.  logits: (B, C); labels: (B,) int."""
    mode = _mode(force)
    if mode == "ref":
        return _ref.softmax_xent_ref(logits, labels)
    interp = mode == "pallas_interpret"
    fused = _fused_xent(interp, tuple(sorted(blocks.items())))
    return fused(logits, labels)


def flash_attention(q, k, v, causal: bool = True, *,
                    force: str | None = None, **blocks):
    mode = _mode(force)
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, causal)
    interp = mode == "pallas_interpret"
    return _flash_pallas(q, k, v, causal=causal, interpret=interp, **blocks)


def ssd_chunk(x, dt_a, b, c, *, force: str | None = None, **blocks):
    mode = _mode(force)
    if mode == "ref":
        f = jax.vmap(_ref.ssd_chunk_ref)
        return f(x, dt_a, b, c)
    interp = mode == "pallas_interpret"
    return _ssd_pallas(x, dt_a, b, c, interpret=interp, **blocks)
