"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on a TPU backend the Pallas kernel is used (compiled);
anywhere else the pure-jnp oracle from ref.py runs — bit-compatible
semantics, so models and tests can call these unconditionally.  Tests that
validate the kernels themselves force the Pallas path with
``force="pallas_interpret"``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.fcnn_layer import fcnn_layer as _fcnn_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.ssd_scan import ssd_chunk as _ssd_pallas

__all__ = ["fcnn_layer", "flash_attention", "ssd_chunk"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(force: str | None) -> str:
    if force is not None:
        return force
    return "pallas" if _on_tpu() else "ref"


def fcnn_layer(x, w, b, activation: str = "sigmoid", *,
               force: str | None = None, **blocks):
    mode = _mode(force)
    if mode == "ref":
        return _ref.fcnn_layer_ref(x, w, b, activation)
    interp = mode == "pallas_interpret"
    return _fcnn_pallas(x, w, b, activation, interpret=interp, **blocks)


def flash_attention(q, k, v, causal: bool = True, *,
                    force: str | None = None, **blocks):
    mode = _mode(force)
    if mode == "ref":
        return _ref.flash_attention_ref(q, k, v, causal)
    interp = mode == "pallas_interpret"
    return _flash_pallas(q, k, v, causal=causal, interpret=interp, **blocks)


def ssd_chunk(x, dt_a, b, c, *, force: str | None = None, **blocks):
    mode = _mode(force)
    if mode == "ref":
        ys, sts, decs = [], [], []
        f = jax.vmap(_ref.ssd_chunk_ref)
        return f(x, dt_a, b, c)
    interp = mode == "pallas_interpret"
    return _ssd_pallas(x, dt_a, b, c, interpret=interp, **blocks)
