"""Deterministic open-loop traffic for the serving subsystem.

A ``TrafficTrace`` is seeded, replayable data in the style of
``runtime.faults.FaultSchedule``: the same ``(scenario, seed)`` pair
produces the same arrival/length event list every run, on every machine,
independent of how many slots or devices the serving engine happens to
have.  Arrivals are open-loop (Poisson, optionally with a burst window),
so a slow server builds a queue instead of slowing the offered load —
the millions-of-users regime, shrunk to a replayable event list.

Prompt/generation lengths are Zipf-distributed over *bucket lists* rather
than free integers: the engine compiles one batch-1 prefill per distinct
prompt length, so lengths must come from a small fixed set (the standard
XLA serving shape-bucket pattern).  Prompt token *content* is derived
per-request from ``(trace seed, rid)`` via ``prompt_tokens`` — also
independent of scheduling, so a request's greedy decode stream is a pure
function of the trace, never of batching, slot placement, or faults.

Scenario presets (``scenario_preset``):

  steady                  Poisson arrivals at a constant rate.
  burst                   low base rate with a windowed multiplier —
                          the queue spikes, then drains.
  drain                   the whole request set arrives almost at once,
                          then arrivals stop while the slots drain.
  device-loss-mid-decode  steady arrivals plus a device-loss event fired
                          at a fixed global decode step (the serving
                          analogue of FaultSchedule.seeded_device_loss).
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

__all__ = [
    "Scenario",
    "RequestEvent",
    "TrafficTrace",
    "SCENARIO_NAMES",
    "scenario_preset",
    "make_traffic",
    "prompt_tokens",
]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named traffic shape + the SLO targets it is judged against.

    ``burst``       (t0_s, t1_s, multiplier): arrival rate is
                    ``rate_rps * multiplier`` inside [t0, t1).
    ``device_loss`` (at_decode_step, n_lost): the engine fires a
                    device-loss event when its global decode-step counter
                    reaches ``at_decode_step``.
    Length buckets are the only lengths the generator emits; Zipf rank 1
    is the *first* bucket, so order buckets most-common-first if you want
    short prompts to dominate.
    """

    name: str
    n_requests: int = 16
    rate_rps: float = 50.0
    burst: tuple[float, float, float] | None = None
    device_loss: tuple[int, int] | None = None
    prompt_buckets: tuple[int, ...] = (8, 16, 32)
    gen_buckets: tuple[int, ...] = (4, 8, 16)
    zipf_a: float = 1.2
    ttft_slo_s: float = 0.5
    tpot_slo_s: float = 0.1

    def __post_init__(self) -> None:
        if self.n_requests < 1:
            raise ValueError("n_requests >= 1")
        if self.rate_rps <= 0:
            raise ValueError("rate_rps > 0")
        for b in (*self.prompt_buckets, *self.gen_buckets):
            if b < 1:
                raise ValueError(f"length buckets must be >= 1, got {b}")

    @property
    def max_len(self) -> int:
        """Deepest sequence any request of this scenario can reach."""
        return max(self.prompt_buckets) + max(self.gen_buckets)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


_PRESETS: dict[str, Scenario] = {
    "steady": Scenario("steady"),
    "burst": Scenario("burst", n_requests=24, rate_rps=20.0,
                      burst=(0.2, 0.5, 10.0)),
    "drain": Scenario("drain", n_requests=24, rate_rps=2000.0),
    "device-loss-mid-decode": Scenario(
        "device-loss-mid-decode", device_loss=(4, 2)),
}

SCENARIO_NAMES: tuple[str, ...] = tuple(_PRESETS)


def scenario_preset(name: str, **overrides) -> Scenario:
    """A named preset, optionally with fields overridden (bucket lists,
    request counts, rates — anything but the name)."""
    if name not in _PRESETS:
        raise KeyError(
            f"unknown scenario {name!r}; presets: {', '.join(_PRESETS)}")
    sc = _PRESETS[name]
    return sc.replace(**overrides) if overrides else sc


@dataclasses.dataclass(frozen=True)
class RequestEvent:
    """One request of a trace: arrival time + shape, no token content
    (content is derived on demand by ``prompt_tokens`` so the trace stays
    model/vocab independent)."""

    rid: int
    arrival_s: float
    prompt_len: int
    gen_len: int

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class TrafficTrace:
    """A replayable, seed-deterministic request list (arrival-sorted)."""

    events: tuple[RequestEvent, ...]
    seed: int
    scenario: str

    def __len__(self) -> int:
        return len(self.events)

    @property
    def rids(self) -> tuple[int, ...]:
        return tuple(e.rid for e in self.events)

    @property
    def duration_s(self) -> float:
        return self.events[-1].arrival_s if self.events else 0.0

    def to_dicts(self) -> list[dict]:
        return [e.to_dict() for e in self.events]


def _zipf_pick(rng: np.random.Generator, buckets: tuple[int, ...],
               a: float) -> int:
    """Zipf over bucket *ranks*: P(bucket k) ∝ 1 / (k+1)^a."""
    p = 1.0 / np.arange(1, len(buckets) + 1, dtype=np.float64) ** a
    p /= p.sum()
    return int(buckets[rng.choice(len(buckets), p=p)])


def _rate_at(sc: Scenario, t: float) -> float:
    if sc.burst is not None:
        t0, t1, mult = sc.burst
        if t0 <= t < t1:
            return sc.rate_rps * mult
    return sc.rate_rps


def make_traffic(sc: Scenario, seed: int) -> TrafficTrace:
    """Generate the scenario's replayable event list.

    The RNG is seeded from ``(seed, crc32(scenario name))`` so two
    scenarios with coincidentally equal parameters still get distinct
    traces, while the same (scenario, seed) is bit-identical across runs.
    Nothing here depends on slot count, device count, or the model.
    """
    rng = np.random.default_rng([seed, zlib.crc32(sc.name.encode())])
    events: list[RequestEvent] = []
    t = 0.0
    for rid in range(sc.n_requests):
        t += float(rng.exponential(1.0 / _rate_at(sc, t)))
        events.append(RequestEvent(
            rid=rid,
            arrival_s=t,
            prompt_len=_zipf_pick(rng, sc.prompt_buckets, sc.zipf_a),
            gen_len=_zipf_pick(rng, sc.gen_buckets, sc.zipf_a),
        ))
    return TrafficTrace(events=tuple(events), seed=seed, scenario=sc.name)


def prompt_tokens(seed: int, event: RequestEvent, vocab: int) -> np.ndarray:
    """Deterministic prompt content for one request: a pure function of
    (trace seed, rid, vocab), independent of scheduling order."""
    if vocab < 1:
        raise ValueError("vocab >= 1")
    rng = np.random.default_rng([seed, event.rid, 1_000_003])
    return rng.integers(0, vocab, size=event.prompt_len,
                        dtype=np.int64).astype(np.int32)
