"""Admission + continuous-batching scheduler (the SlotManager grown up).

The old ``launch.serve`` prototype refilled slots by re-running a
*whole-batch* prefill, overwriting the shared KV cache and destroying
every in-flight sequence's state.  Here admission is per-slot: a newly
admitted request is prefilled alone (batch-1, shape-bucketed) and its
cache rows are merged into the batch cache at its slot index only — an
in-flight slot's cache state is never touched by someone else's
admission.  Prefill and decode are separate steps: each engine iteration
first admits + prefills into free slots, then runs exactly one batched
decode step for everything resident.

The engine is model-agnostic: it drives a ``ModelRunner`` (the jitted
JAX implementation lives in ``serve.runner``; tests substitute a fake)
and a ``Clock`` (wall clock for real serving, ``TickClock`` for
deterministic virtual-time tests).

Elasticity: a device-loss event (scenario-scheduled, mirroring
``FaultSchedule``) or a sustained SLO violation consults the autoscaler
(``serve.elastic.ServeAutoscaler`` — Lemma 1 on the survivors), the
runner is rebuilt for the new device set / slot count, and every
in-flight request is restarted from its prompt: greedy decode is a pure
function of the prompt, so the replayed stream is identical and the
fault costs latency, never tokens.  Queued and restarted requests are
re-admitted in arrival order (FIFO fairness).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Protocol

import numpy as np

from repro.serve.metrics import ServeMetrics, SLOReport
from repro.serve.traffic import Scenario, TrafficTrace, prompt_tokens

__all__ = [
    "Request",
    "SlotManager",
    "ModelRunner",
    "TickClock",
    "WallClock",
    "ServingEngine",
    "EngineResult",
]


@dataclasses.dataclass
class Request:
    """One in-flight request.  ``out`` accumulates generated tokens (the
    prefill's first token included); ``done`` flips when ``gen_len``
    tokens exist."""

    rid: int
    prompt: np.ndarray
    gen_len: int
    arrival_s: float = 0.0
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False
    restarts: int = 0

    @property
    def max_new(self) -> int:        # old launch.serve.Request field name
        return self.gen_len


class SlotManager:
    """Continuous batching over a fixed-size slot set.

    Invariants (pinned by tests/test_serve_scheduler.py):
      * a request occupies at most one slot at a time;
      * ``fill`` admits strictly in queue (FIFO) order;
      * ``release_done`` moves a finished request to ``finished`` exactly
        once and frees its slot.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots >= 1")
        self.slots: list[Request | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []

    @property
    def n_slots(self) -> int:
        return len(self.slots)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def fill(self) -> list[tuple[int, Request]]:
        """Assign queued requests to free slots in FIFO order; returns the
        newly filled (slot, request) pairs."""
        assigned: list[tuple[int, Request]] = []
        for i, s in enumerate(self.slots):
            if s is None and self.queue:
                req = self.queue.popleft()
                if any(r is req for r in self.slots):
                    raise RuntimeError(
                        f"request {req.rid} already occupies a slot")
                self.slots[i] = req
                assigned.append((i, req))
        return assigned

    def release_done(self) -> list[Request]:
        out = []
        for i, s in enumerate(self.slots):
            if s is not None and s.done:
                self.finished.append(s)
                self.slots[i] = None
                out.append(s)
        return out

    def running(self) -> list[tuple[int, Request]]:
        return [(i, s) for i, s in enumerate(self.slots)
                if s is not None and not s.done]

    def drain_slots(self) -> list[Request]:
        """Pull every resident request out of its slot (capacity change:
        the caller restarts + resubmits them)."""
        out = [s for s in self.slots if s is not None]
        self.slots = [None] * len(self.slots)
        return out

    @property
    def active(self) -> bool:
        return any(s is not None for s in self.slots) or bool(self.queue)


class ModelRunner(Protocol):
    """What the engine needs from a model backend."""

    vocab: int
    n_devices: int

    def prefill(self, slot: int, prompt: np.ndarray) -> int:
        """Prefill one request into ``slot`` (merging only that slot's
        cache rows) and return its first generated token."""
        ...

    def decode(self, last_tokens: np.ndarray) -> np.ndarray:
        """One batched greedy decode step: (n_slots,) int32 in/out."""
        ...

    def rebuild(self, n_devices: int | None = None,
                n_slots: int | None = None) -> None:
        """Re-place params and rebuild steps for a new device count and/or
        slot count (all cache state is discarded)."""
        ...


class TickClock:
    """Virtual time for deterministic tests: each engine phase advances a
    fixed dt, idle periods jump to the next arrival."""

    def __init__(self, dt: float = 1.0):
        self.dt = dt
        self._t = 0.0

    def now(self) -> float:
        return self._t

    def advance(self, dt: float | None = None) -> None:
        self._t += self.dt if dt is None else dt

    def skip_to(self, t: float) -> None:
        self._t = max(self._t, t)


class WallClock:
    """Real time, with idle periods skipped instantly: latencies are real
    compute/queueing time, but an idle open-loop gap costs nothing."""

    def __init__(self) -> None:
        self._t0 = time.perf_counter()
        self._offset = 0.0

    def now(self) -> float:
        return time.perf_counter() - self._t0 + self._offset

    def advance(self, dt: float | None = None) -> None:
        pass                                    # real time advances itself

    def skip_to(self, t: float) -> None:
        now = self.now()
        if t > now:
            self._offset += t - now


@dataclasses.dataclass
class EngineResult:
    streams: dict[int, list[int]]            # rid -> generated tokens
    metrics: ServeMetrics
    slo: SLOReport
    n_prefills: int
    n_decode_steps: int
    replans: list                            # elastic.ReplanDecision


class ServingEngine:
    """Open-loop driver: admits trace arrivals, prefills into free slots,
    decodes the resident batch, and reacts to device loss / SLO pressure
    via the autoscaler."""

    def __init__(self, runner: ModelRunner, n_slots: int,
                 clock=None, autoscaler=None,
                 slo_check_every: int = 0, slo_patience: int = 2,
                 slo_window: int = 8):
        self.runner = runner
        self.n_slots = n_slots
        self.clock = clock
        self.autoscaler = autoscaler
        self.slo_check_every = slo_check_every
        self.slo_patience = slo_patience
        self.slo_window = slo_window

    # -- elastic transitions ------------------------------------------------

    def _rescale(self, mgr: SlotManager, metrics: ServeMetrics,
                 decision) -> SlotManager:
        """Apply a ReplanDecision: rebuild the runner, restart in-flight
        requests from their prompts, re-admit everything in arrival
        order."""
        inflight = mgr.drain_slots()
        for req in inflight:
            req.out = []
            req.done = False
            req.restarts += 1
            metrics.on_restart(req.rid)
        backlog = sorted([*inflight, *mgr.queue],
                         key=lambda r: (r.arrival_s, r.rid))
        self.runner.rebuild(n_devices=decision.to_devices,
                            n_slots=decision.to_slots)
        new_mgr = SlotManager(decision.to_slots)
        new_mgr.finished = mgr.finished
        for req in backlog:
            new_mgr.submit(req)
        return new_mgr

    def _device_loss(self, mgr: SlotManager, metrics: ServeMetrics,
                     n_lost: int, now: float, replans: list) -> SlotManager:
        if self.autoscaler is not None:
            decision = self.autoscaler.on_device_loss(n_lost, now)
        else:
            from repro.serve.elastic import ReplanDecision
            decision = ReplanDecision(
                reason="device_loss", at_s=now,
                from_devices=self.runner.n_devices,
                to_devices=max(1, self.runner.n_devices - n_lost),
                from_slots=mgr.n_slots, to_slots=mgr.n_slots)
        replans.append(decision)
        return self._rescale(mgr, metrics, decision)

    # -- main loop ----------------------------------------------------------

    def run(self, trace: TrafficTrace,
            scenario: Scenario | None = None) -> EngineResult:
        clock = self.clock if self.clock is not None else WallClock()
        metrics = ServeMetrics()
        mgr = SlotManager(self.n_slots)
        replans: list = []
        streams: dict[int, list[int]] = {}
        pending = deque(sorted(trace.events,
                               key=lambda e: (e.arrival_s, e.rid)))
        loss_at, loss_n = (scenario.device_loss
                           if scenario is not None and scenario.device_loss
                           else (None, 0))
        n_prefills = n_decode_steps = 0
        slo_strikes = 0

        def release(now: float) -> None:
            for req in mgr.release_done():
                metrics.on_finish(req.rid, now, n_gen=len(req.out))
                streams[req.rid] = list(req.out)

        while pending or mgr.active:
            now = clock.now()
            # 1. open-loop arrivals
            while pending and pending[0].arrival_s <= now:
                ev = pending.popleft()
                req = Request(
                    rid=ev.rid,
                    prompt=prompt_tokens(trace.seed, ev, self.runner.vocab),
                    gen_len=ev.gen_len, arrival_s=ev.arrival_s)
                mgr.submit(req)
                metrics.on_submit(ev.rid, ev.arrival_s, ev.prompt_len,
                                  ev.gen_len)
            # 2. admission: per-slot prefill, in-flight slots untouched
            for slot, req in mgr.fill():
                metrics.on_admit(req.rid, clock.now())
                first = self.runner.prefill(slot, req.prompt)
                clock.advance()
                n_prefills += 1
                if not req.out:         # restart replays deterministically
                    metrics.on_first_token(req.rid, clock.now())
                req.out.append(first)
                if len(req.out) >= req.gen_len:
                    req.done = True
            release(clock.now())
            # 3. one batched decode step for everything resident
            running = mgr.running()
            if running:
                last = np.zeros(mgr.n_slots, np.int32)
                for i, req in running:
                    last[i] = req.out[-1]
                nxt = self.runner.decode(last)
                clock.advance()
                n_decode_steps += 1
                for i, req in running:
                    req.out.append(int(nxt[i]))
                    if len(req.out) >= req.gen_len:
                        req.done = True
                release(clock.now())
            elif pending and not mgr.queue:
                clock.skip_to(pending[0].arrival_s)
            # 4. scenario-scheduled device loss at a global decode step
            if loss_at is not None and n_decode_steps >= loss_at:
                mgr = self._device_loss(mgr, metrics, loss_n, clock.now(),
                                        replans)
                loss_at = None
            # 5. sustained SLO violation -> autoscale
            if (self.autoscaler is not None and self.slo_check_every
                    and scenario is not None and n_decode_steps
                    and n_decode_steps % self.slo_check_every == 0):
                p99 = metrics.recent_p99_ttft(self.slo_window)
                if p99 == p99 and p99 > scenario.ttft_slo_s:  # nan-safe
                    slo_strikes += 1
                else:
                    slo_strikes = 0
                if slo_strikes >= self.slo_patience:
                    decision = self.autoscaler.on_slo_violation(
                        clock.now(), p99)
                    slo_strikes = 0
                    if decision is not None:
                        replans.append(decision)
                        mgr = self._rescale(mgr, metrics, decision)

        slo = (metrics.report(scenario.ttft_slo_s, scenario.tpot_slo_s)
               if scenario is not None else metrics.report())
        return EngineResult(streams=streams, metrics=metrics, slo=slo,
                            n_prefills=n_prefills,
                            n_decode_steps=n_decode_steps, replans=replans)
