"""Lemma-1 elastic autoscaling for the serving engine.

The paper's core result — the closed-form optimal per-stage core count,
re-derived whenever the core set changes — is the allocation oracle here
exactly as it is for training: ``runtime.elastic.ElasticPlanner`` wraps
Lemma 1, and ``ElasticPlanner.replan_program`` runs the full degraded-mode
machinery (Lemma-1 plan on the survivors, period-program compile, static
validation), so a serving replan is priced and verified by the same code
path the fault-recovery tests pin.

Capacity policy: the decode batch (slot count) tracks the Lemma-1-priced
epoch throughput of the ring.  Losing cores makes the replanned epoch
slower, so the autoscaler shrinks the admitted batch proportionally
(protecting per-token latency instead of queueing decode work the ring
can no longer clear); a sustained TTFT SLO violation grows it back
toward ``max_slots`` after re-consulting the oracle.

Every decision is a ``ReplanDecision`` (serialized into serving_bench's
JSON rows), carrying the Lemma-1 core allocation and the replanned
epoch price that justified it.
"""

from __future__ import annotations

import dataclasses

from repro.core.allocation import MappingStrategy
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.runtime.elastic import ElasticPlanner

__all__ = ["ReplanDecision", "ServeAutoscaler"]


@dataclasses.dataclass(frozen=True)
class ReplanDecision:
    """One autoscaling action: why, when, and the device/slot transition.

    ``epoch_s`` is the Lemma-1-replanned epoch price on ``to_devices``
    cores (compute + transitions, the program's cost annotations);
    ``lemma1_cores`` the per-stage optimal allocation that produced it.
    """

    reason: str                       # "device_loss" | "slo_violation"
    at_s: float
    from_devices: int
    to_devices: int
    from_slots: int
    to_slots: int
    epoch_s: float | None = None
    lemma1_cores: tuple[int, ...] | None = None

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.lemma1_cores is not None:
            d["lemma1_cores"] = list(self.lemma1_cores)
        return d


def _default_workload() -> FCNNWorkload:
    from repro.configs.nn_benchmarks import workload
    return workload("NN1", batch_size=32)


def _default_cfg(n_devices: int) -> ONoCConfig:
    from repro.configs.nn_benchmarks import onoc_config
    return dataclasses.replace(onoc_config(lambda_max=64), m=n_devices)


class ServeAutoscaler:
    """The serving engine's allocation oracle.

    ``on_device_loss`` re-runs Lemma 1 on the survivors (via
    ``ElasticPlanner.replan_program``, which also compiles + statically
    validates the survivors' period program — a bad replan fails *here*,
    before the engine rebuilds anything) and scales the slot count by the
    replanned epoch-throughput ratio.  ``on_slo_violation`` grows slots
    toward ``max_slots`` after re-deriving the allocation for the current
    membership; it returns None when already at capacity.
    """

    def __init__(self, n_devices: int, n_slots: int, *,
                 workload: FCNNWorkload | None = None,
                 base_cfg: ONoCConfig | None = None,
                 strategy: MappingStrategy = MappingStrategy.ORRM,
                 min_slots: int = 1, max_slots: int | None = None):
        self.workload = workload if workload is not None else _default_workload()
        self.base_cfg = (base_cfg if base_cfg is not None
                         else _default_cfg(n_devices))
        self.planner = ElasticPlanner(self.workload, self.base_cfg, strategy)
        self.n_devices = n_devices
        self.n_slots = n_slots
        self.base_slots = n_slots
        self.min_slots = min_slots
        self.max_slots = max_slots if max_slots is not None else 2 * n_slots
        self.events: list[ReplanDecision] = []
        self._base_epoch_s = self._replan(n_devices)[0]

    def _replan(self, n: int) -> tuple[float, tuple[int, ...]]:
        """Lemma 1 + compile + static validation on an ``n``-core ring;
        returns (epoch price, per-stage optimal cores)."""
        _, _, program = self.planner.replan_program(n)
        _, cores, _ = self.planner.plan_for(n)
        return float(program.compute_s + program.comm_s), tuple(cores)

    def _clamp(self, slots: int) -> int:
        return max(self.min_slots, min(self.max_slots, slots))

    def on_device_loss(self, n_lost: int, now: float) -> ReplanDecision:
        n_new = max(1, self.n_devices - n_lost)
        epoch_s, cores = self._replan(n_new)
        to_slots = self._clamp(round(
            self.base_slots * self._base_epoch_s / epoch_s))
        decision = ReplanDecision(
            reason="device_loss", at_s=now,
            from_devices=self.n_devices, to_devices=n_new,
            from_slots=self.n_slots, to_slots=to_slots,
            epoch_s=epoch_s, lemma1_cores=cores)
        self.n_devices = n_new
        self.n_slots = to_slots
        self.events.append(decision)
        return decision

    def on_slo_violation(self, now: float,
                         p99_ttft_s: float) -> ReplanDecision | None:
        to_slots = self._clamp(self.n_slots + max(1, self.n_slots // 2))
        if to_slots == self.n_slots:
            return None                      # already at capacity
        epoch_s, cores = self._replan(self.n_devices)
        decision = ReplanDecision(
            reason="slo_violation", at_s=now,
            from_devices=self.n_devices, to_devices=self.n_devices,
            from_slots=self.n_slots, to_slots=to_slots,
            epoch_s=epoch_s, lemma1_cores=cores)
        self.n_slots = to_slots
        self.events.append(decision)
        return decision
