"""Jitted model backend for the serving engine.

Shapes are static everywhere XLA cares:

  * decode runs at a fixed ``(n_slots, 1)`` batch against a fixed
    ``max_len``-deep cache (``launch.steps.build_decode_step``, cache
    donated — in-place update at scale);
  * prefill runs batch-1 at the request's *length bucket* — one compiled
    step per distinct prompt length (lengths come from the traffic
    generator's small bucket list), so admission never recompiles in
    steady state;
  * admission merges the batch-1 prefill cache into the batch cache at
    the target slot index only.  The merge walks ``model.cache_axes()``
    and updates each leaf along its ``cache_batch`` axis with
    ``dynamic_update_slice_in_dim`` — one donated jitted call, generic
    across families (dense KV, SSM state, hybrid), and by construction
    unable to touch any other slot's rows.  This is the fix for the old
    ``launch/serve.py`` whole-batch-refill bug.

Per-slot ``len`` rows make in-flight sequences independent: each slot
decodes at its own depth, and a freshly admitted slot starts at its
prompt length without disturbing neighbours.  Greedy argmax decode is
row-wise deterministic, so a request's stream is a pure function of its
prompt — the property the refill and device-loss tests pin.

``rebuild`` re-places the (host-canonical) params onto a new device
mesh and/or slot count — the elastic path.  Cache state is discarded;
the engine restarts in-flight requests from their prompts (identical
streams, see scheduler docs).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch import steps as steps_lib
from repro.models.api import Model, get_model

__all__ = ["JaxModelRunner", "snap_prompt_buckets"]


def snap_prompt_buckets(cfg: ModelConfig,
                        buckets: tuple[int, ...]) -> tuple[int, ...]:
    """SSM/hybrid chunked prefill wants seq % ssm_chunk == 0: round each
    bucket up to the chunk.  Other families pass through (deduped,
    sorted)."""
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_chunk > 1:
        c = cfg.ssm_chunk
        buckets = tuple(-(-b // c) * c for b in buckets)
    return tuple(sorted(set(buckets)))


def _make_cache_merge(model: Model):
    """One donated jitted merge: write a batch-1 cache into the batch
    cache at ``slot`` along each leaf's ``cache_batch`` axis."""
    axes = model.cache_axes()

    def merge(full, one, slot):
        leaves, treedef = jax.tree_util.tree_flatten(full)
        ones = treedef.flatten_up_to(one)
        axs = treedef.flatten_up_to(axes)
        out = []
        for f, o, ax in zip(leaves, ones, axs):
            i = list(ax).index("cache_batch")
            out.append(jax.lax.dynamic_update_slice_in_dim(
                f, o.astype(f.dtype), slot, axis=i))
        return jax.tree_util.tree_unflatten(treedef, out)

    return jax.jit(merge, donate_argnums=(0,))


class JaxModelRunner:
    """ModelRunner over the jitted prefill/decode step builders."""

    def __init__(self, cfg: ModelConfig, n_slots: int, max_len: int,
                 devices=None, seed: int = 0):
        if cfg.family in ("vlm", "encdec"):
            raise ValueError("the serving runner drives token-LM archs "
                             f"(got family {cfg.family!r})")
        self.cfg = cfg
        self.vocab = cfg.vocab_size
        self.max_len = max_len
        self.model = get_model(cfg)
        self._host_params = self.model.init(jax.random.PRNGKey(seed))
        self._all_devices = (list(devices) if devices is not None
                             else list(jax.devices()))
        self._build(self._all_devices, n_slots)

    # -- construction / elastic rebuild -------------------------------------

    def _build(self, devices, n_slots: int) -> None:
        self.devices = list(devices)
        self.n_devices = len(self.devices)
        self.n_slots = n_slots
        self.mesh = Mesh(np.asarray(self.devices), ("data",))
        decode, p_sh, _, c_sh = steps_lib.build_decode_step(
            self.model, self.mesh,
            ShapeSpec("serve_decode", self.max_len, n_slots, "decode"))
        self._decode_step = decode
        self.params = jax.device_put(self._host_params, p_sh)
        self.cache = jax.device_put(
            self.model.init_cache(n_slots, self.max_len), c_sh)
        self._prefill_steps: dict[int, object] = {}
        self._merge = _make_cache_merge(self.model)

    def rebuild(self, n_devices: int | None = None,
                n_slots: int | None = None) -> None:
        """Elastic transition: survivors are the first ``n_devices`` of
        the original device list (the CPU-ring convention the fault tests
        use); params are re-placed from the host-canonical copy, all
        compiled steps and cache state are rebuilt."""
        devices = (self._all_devices[:n_devices] if n_devices is not None
                   else self.devices)
        if not devices:
            raise ValueError("rebuild needs at least one device")
        self._build(devices, n_slots if n_slots is not None else self.n_slots)

    # -- serving steps -------------------------------------------------------

    def _prefill_for(self, length: int):
        fn = self._prefill_steps.get(length)
        if fn is None:
            fn, *_ = steps_lib.build_prefill_step(
                self.model, self.mesh,
                ShapeSpec("serve_prefill", length, 1, "prefill"),
                max_len=self.max_len)
            self._prefill_steps[length] = fn
        return fn

    def prefill(self, slot: int, prompt: np.ndarray) -> int:
        if not 0 <= slot < self.n_slots:
            raise IndexError(f"slot {slot} out of range [0, {self.n_slots})")
        if len(prompt) + 1 > self.max_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot decode into a "
                f"max_len={self.max_len} cache")
        fn = self._prefill_for(len(prompt))
        logits, one_cache = fn(
            self.params,
            {"tokens": jnp.asarray(np.asarray(prompt, np.int32)[None, :])})
        self.cache = self._merge(self.cache, one_cache, jnp.int32(slot))
        return int(np.asarray(jnp.argmax(logits[0, -1])))

    def decode(self, last_tokens: np.ndarray) -> np.ndarray:
        logits, self.cache = self._decode_step(
            self.params, self.cache,
            {"tokens": jnp.asarray(np.asarray(last_tokens,
                                              np.int32)[:, None])})
        return np.asarray(jnp.argmax(logits[:, -1, :], axis=-1),
                          dtype=np.int32)

    # -- warmup --------------------------------------------------------------

    def warmup(self, prompt_buckets: tuple[int, ...]) -> None:
        """Compile every prefill bucket + the decode step up front so
        measured latencies are serving work, not XLA compiles (real
        serving stacks warm exactly this way).  Cache state is reset
        afterwards."""
        for b in prompt_buckets:
            fn = self._prefill_for(b)
            fn(self.params, {"tokens": jnp.zeros((1, b), jnp.int32)})
        _, warmed = self._decode_step(
            self.params, self.cache,
            {"tokens": jnp.zeros((self.n_slots, 1), jnp.int32)})
        # decode donated the cache buffers; restore a clean zero cache
        self.cache = jax.device_put(
            self.model.init_cache(self.n_slots, self.max_len),
            jax.tree.map(lambda x: x.sharding, warmed))
