"""repro.serve — load-tested continuous-batching serving (ISSUE 10).

The promoted, fixed descendant of the old ``launch/serve.py`` prototype:

  traffic.py    seeded open-loop traffic (Poisson arrivals, Zipf length
                buckets, replayable scenario presets).
  scheduler.py  SlotManager + the continuous-batching ServingEngine
                (per-slot admission prefill, FIFO fairness,
                prefill/decode step separation, elastic transitions).
  runner.py     jitted JAX backend (bucketed batch-1 prefill, per-slot
                cache merge, fixed-shape batched decode).
  metrics.py    TTFT/TPOT/e2e percentiles, throughput/goodput SLO report.
  elastic.py    Lemma-1 autoscaling oracle over runtime.elastic.

See README.md in this package for the API walkthrough and the SLO field
glossary; ``benchmarks/serving_bench.py`` runs every scenario preset.
"""

from repro.serve.elastic import ReplanDecision, ServeAutoscaler
from repro.serve.metrics import RequestRecord, ServeMetrics, SLOReport
from repro.serve.runner import JaxModelRunner, snap_prompt_buckets
from repro.serve.scheduler import (
    EngineResult,
    ModelRunner,
    Request,
    ServingEngine,
    SlotManager,
    TickClock,
    WallClock,
)
from repro.serve.traffic import (
    RequestEvent,
    Scenario,
    SCENARIO_NAMES,
    TrafficTrace,
    make_traffic,
    prompt_tokens,
    scenario_preset,
)

__all__ = [
    "ReplanDecision",
    "ServeAutoscaler",
    "RequestRecord",
    "ServeMetrics",
    "SLOReport",
    "JaxModelRunner",
    "snap_prompt_buckets",
    "EngineResult",
    "ModelRunner",
    "Request",
    "ServingEngine",
    "SlotManager",
    "TickClock",
    "WallClock",
    "RequestEvent",
    "Scenario",
    "SCENARIO_NAMES",
    "TrafficTrace",
    "make_traffic",
    "prompt_tokens",
    "scenario_preset",
]
