"""Per-request latency accounting + SLO reporting for the serving engine.

One ``RequestRecord`` per request tracks the canonical serving
timestamps — arrival (from the trace), admission to a slot, first token
(TTFT ends here), completion — plus restart count (a request is restarted
from its prompt when a device loss or capacity change invalidates its KV
cache; greedy decode makes the replayed stream identical, so restarts
cost latency, never correctness).

``ServeMetrics`` enforces the lifecycle invariants the scheduler tests
pin: a request is submitted once, and finishes exactly once — double
submission or double finish raises instead of silently corrupting the
report.

``SLOReport`` field glossary (all times in seconds):

  p50_ttft_s / p99_ttft_s  time-to-first-token percentiles
                           (first token − arrival; includes queueing).
  p50_tpot_s / p99_tpot_s  time-per-output-token percentiles
                           ((finish − first token) / (n_gen − 1)).
  p50_e2e_s  / p99_e2e_s   end-to-end latency percentiles.
  throughput_tok_s         generated tokens / makespan (first arrival to
                           last completion).
  goodput_tok_s            same numerator restricted to requests that met
                           BOTH the TTFT and TPOT SLO targets — the
                           throughput that actually counted.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["RequestRecord", "ServeMetrics", "SLOReport"]


@dataclasses.dataclass
class RequestRecord:
    rid: int
    arrival_s: float
    prompt_len: int
    gen_len: int
    admit_s: float | None = None
    first_token_s: float | None = None
    finish_s: float | None = None
    n_gen: int = 0
    restarts: int = 0

    @property
    def ttft_s(self) -> float | None:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> float | None:
        if self.finish_s is None or self.first_token_s is None:
            return None
        return ((self.finish_s - self.first_token_s)
                / max(self.n_gen - 1, 1))

    @property
    def e2e_s(self) -> float | None:
        if self.finish_s is None:
            return None
        return self.finish_s - self.arrival_s

    def meets(self, ttft_slo_s: float, tpot_slo_s: float) -> bool:
        return (self.finish_s is not None
                and self.ttft_s <= ttft_slo_s
                and self.tpot_s <= tpot_slo_s)


def _pct(vals: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals), q)) if vals else float("nan")


@dataclasses.dataclass(frozen=True)
class SLOReport:
    n_submitted: int
    n_finished: int
    n_restarts: int
    p50_ttft_s: float
    p99_ttft_s: float
    p50_tpot_s: float
    p99_tpot_s: float
    p50_e2e_s: float
    p99_e2e_s: float
    throughput_tok_s: float
    goodput_tok_s: float
    n_slo_ok: int
    makespan_s: float

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


class ServeMetrics:
    """Collects RequestRecords as the engine runs; lifecycle-checked."""

    def __init__(self) -> None:
        self.records: dict[int, RequestRecord] = {}

    def on_submit(self, rid: int, arrival_s: float, prompt_len: int,
                  gen_len: int) -> None:
        if rid in self.records:
            raise RuntimeError(f"request {rid} submitted twice")
        self.records[rid] = RequestRecord(
            rid=rid, arrival_s=arrival_s, prompt_len=prompt_len,
            gen_len=gen_len)

    def _rec(self, rid: int) -> RequestRecord:
        try:
            return self.records[rid]
        except KeyError:
            raise RuntimeError(f"request {rid} was never submitted") from None

    def on_admit(self, rid: int, now: float) -> None:
        rec = self._rec(rid)
        if rec.admit_s is None:          # restarts keep the first admission
            rec.admit_s = now

    def on_first_token(self, rid: int, now: float) -> None:
        rec = self._rec(rid)
        if rec.first_token_s is None:    # restarts keep the first TTFT
            rec.first_token_s = now

    def on_restart(self, rid: int) -> None:
        self._rec(rid).restarts += 1

    def on_finish(self, rid: int, now: float, n_gen: int) -> None:
        rec = self._rec(rid)
        if rec.finish_s is not None:
            raise RuntimeError(f"request {rid} finished twice")
        rec.finish_s = now
        rec.n_gen = n_gen

    @property
    def finished(self) -> list[RequestRecord]:
        return [r for r in self.records.values() if r.finish_s is not None]

    def report(self, ttft_slo_s: float = float("inf"),
               tpot_slo_s: float = float("inf")) -> SLOReport:
        done = self.finished
        ttft = [r.ttft_s for r in done]
        tpot = [r.tpot_s for r in done]
        e2e = [r.e2e_s for r in done]
        if done:
            makespan = (max(r.finish_s for r in done)
                        - min(r.arrival_s for r in done))
        else:
            makespan = 0.0
        denom = max(makespan, 1e-9)
        ok = [r for r in done if r.meets(ttft_slo_s, tpot_slo_s)]
        return SLOReport(
            n_submitted=len(self.records),
            n_finished=len(done),
            n_restarts=sum(r.restarts for r in self.records.values()),
            p50_ttft_s=_pct(ttft, 50), p99_ttft_s=_pct(ttft, 99),
            p50_tpot_s=_pct(tpot, 50), p99_tpot_s=_pct(tpot, 99),
            p50_e2e_s=_pct(e2e, 50), p99_e2e_s=_pct(e2e, 99),
            throughput_tok_s=sum(r.n_gen for r in done) / denom,
            goodput_tok_s=sum(r.n_gen for r in ok) / denom,
            n_slo_ok=len(ok),
            makespan_s=makespan,
        )

    def recent_p99_ttft(self, window: int = 8) -> float:
        """p99 TTFT over the most recently *finished* requests — the
        autoscaler's sustained-violation signal."""
        done = sorted(self.finished, key=lambda r: r.finish_s)[-window:]
        return _pct([r.ttft_s for r in done], 99)
