"""Routing and Wavelength Assignment (RWA) — paper Section 4.6 / Fig. 6.

The manager core computes the optimal core counts; the RWA turns each
period transition into a *wavelength matrix* WM where WM[s, d] = k means
sender core s talks to receiver core d on wavelength λ_k.  With m_i senders
and λ_max wavelengths, senders are batched into ceil(m_i / λ_max) TDM time
slots; within a slot every sender broadcasts on its own wavelength to all
receivers (the ring drop-filters tap a fraction of the signal, Fig. 3).

Transmission direction is clockwise in FP and counter-clockwise in BP
(paper Section 4.6).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .allocation import Mapping

__all__ = ["TimeSlot", "WavelengthSchedule", "assign_wavelengths", "schedule_epoch"]

UNASSIGNED = -1


@dataclasses.dataclass(frozen=True)
class TimeSlot:
    """One TDM slot: senders transmit concurrently on distinct wavelengths."""

    senders: tuple[int, ...]          # core ids
    wavelengths: tuple[int, ...]      # λ index per sender, same order
    receivers: tuple[int, ...]        # all receivers (broadcast)


@dataclasses.dataclass(frozen=True)
class WavelengthSchedule:
    """All TDM slots of one period transition + the dense WM matrix."""

    period: int                       # sending period
    direction: str                    # "cw" (FP) or "ccw" (BP)
    slots: tuple[TimeSlot, ...]
    wm: np.ndarray                    # (m, m) int matrix, UNASSIGNED where none

    @property
    def n_slots(self) -> int:
        return len(self.slots)


def assign_wavelengths(
    senders: Sequence[int],
    receivers: Sequence[int],
    lambda_max: int,
    m: int,
    period: int = 0,
    direction: str = "cw",
) -> WavelengthSchedule:
    """Build the WM matrix and TDM slots for one period transition.

    Wavelengths are assigned round-robin (sender j in a slot gets λ_j), the
    schedule Fig. 6 shows: λ_1..λ_k for the k concurrent senders of a slot,
    wavelengths reused across slots.
    """
    if lambda_max < 1:
        raise ValueError("lambda_max >= 1")
    senders = list(dict.fromkeys(int(s) for s in senders))   # stable unique
    receivers = tuple(dict.fromkeys(int(r) for r in receivers))
    wm = np.full((m, m), UNASSIGNED, dtype=np.int32)
    slots: list[TimeSlot] = []
    for off in range(0, len(senders), lambda_max):
        batch = senders[off : off + lambda_max]
        lams = tuple(range(len(batch)))
        for s, lam in zip(batch, lams):
            for r in receivers:
                if r != s:
                    wm[s, r] = lam
        slots.append(TimeSlot(senders=tuple(batch), wavelengths=lams,
                              receivers=receivers))
    return WavelengthSchedule(
        period=period, direction=direction, slots=tuple(slots), wm=wm
    )


def schedule_epoch(mapping: Mapping, lambda_max: int) -> list[WavelengthSchedule]:
    """RWA schedules for every communicating period transition of one epoch.

    Communicating transitions (see onoc_model.comm_time): FP periods
    2..l-1 send to the next FP period; BP periods l+1..2l-1 send to the next
    BP period.  Periods 1, l and 2l send nothing (Eq. 6); the period-1 ->
    period-2 hand-off is folded into Period 0/1 loading in the paper's model,
    but the physical broadcast still needs wavelengths, so we emit its
    schedule too, tagged period=1 (benchmarks may exclude it to match
    Eq. (6) exactly).
    """
    l = mapping.l
    out: list[WavelengthSchedule] = []
    for i in range(1, 2 * l):
        senders = mapping.window(i)
        receivers = mapping.window(i + 1)
        if i in (l, 2 * l):
            continue  # no send out of period l (loss is local) per Eq. (6)
        direction = "cw" if i < l else "ccw"
        out.append(
            assign_wavelengths(
                senders, receivers, lambda_max, mapping.m, period=i,
                direction=direction,
            )
        )
    return out
