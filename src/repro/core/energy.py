"""Energy model for ONoC vs ENoC — paper Section 5 (Fig. 9 / Fig. 10b).

The paper computes energy with DSENT-derived constants and the model of
Grani & Bartolini [22]:

  ONoC total = static (laser + MR thermal tuning + core leakage) × T_epoch
             + dynamic (E/O + O/E conversion per bit + core compute energy)
  ENoC total = static (router + core leakage) × T_epoch
             + dynamic (per-bit per-hop router+link energy + compute energy)

Laser power is derived from the worst-case insertion loss (Eq. 19), the
receiver sensitivity and the laser wall-plug efficiency (30%, Table 5) —
longer paths through more optical elements need exponentially more laser
power (dB → linear), which is how the mapping strategy's max path length
(Table 2) feeds energy.

Constants below are DSENT-class values from the ONoC literature; they are
configuration, not measurement — EXPERIMENTS.md treats only *relative*
ONoC/ENoC results as reproduction targets, matching the paper's own use.
"""

from __future__ import annotations

import dataclasses

from .analyses import OpticalLossParams, insertion_loss_db, max_path_length
from .allocation import Mapping
from .simulator import EpochTrace

__all__ = ["EnergyParams", "EnergyBreakdown", "onoc_energy", "enoc_energy"]


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    # --- shared / core ---
    core_active_w: float = 0.20          # per-core active power (compute)
    core_idle_w: float = 0.02            # per-core leakage
    # --- ONoC ---
    eo_oe_pj_per_bit: float = 1.0        # modulator + photodetector dynamic
    mr_tuning_uw: float = 20.0           # per-MR thermal tuning (static)
    mrs_per_router: int = 16             # MRs in a configurable router (Fig. 3)
    receiver_sensitivity_dbm: float = -20.0
    laser_efficiency: float = 0.30       # Table 5
    # --- ENoC ---
    router_pj_per_bit: float = 0.60      # per-hop router traversal
    link_pj_per_bit: float = 0.25        # per-hop link traversal
    router_leak_w: float = 0.005         # per-router static
    state_transition_nj: float = 5.0     # per active<->idle transition (both)


@dataclasses.dataclass(frozen=True)
class EnergyBreakdown:
    static_j: float
    dynamic_j: float
    compute_j: float

    @property
    def total_j(self) -> float:
        return self.static_j + self.dynamic_j + self.compute_j


def _laser_power_w(mapping: Mapping, p: EnergyParams,
                   loss: OpticalLossParams | None = None) -> float:
    """Off-chip laser power needed to close the worst-case link budget."""
    hops = max_path_length(mapping)
    il_db = insertion_loss_db(max(1, hops + 1), loss)
    # required optical output = sensitivity + losses, per wavelength
    p_out_dbm = p.receiver_sensitivity_dbm + il_db
    p_out_w = 10 ** (p_out_dbm / 10) / 1000.0
    return p_out_w / p.laser_efficiency


def onoc_energy(
    trace: EpochTrace,
    mapping: Mapping,
    n_state_transitions: int = 0,
    params: EnergyParams | None = None,
    loss: OpticalLossParams | None = None,
) -> EnergyBreakdown:
    p = params or EnergyParams()
    t = trace.total_s
    n_active = len(mapping.active_cores())

    laser_w = _laser_power_w(mapping, p, loss)
    tuning_w = p.mr_tuning_uw * 1e-6 * p.mrs_per_router * n_active
    idle_w = p.core_idle_w * mapping.m
    static = (laser_w + tuning_w + idle_w) * t

    bits = trace.total_bytes * 8.0
    dynamic = bits * p.eo_oe_pj_per_bit * 1e-12
    dynamic += n_state_transitions * p.state_transition_nj * 1e-9

    compute = float(trace.core_busy_s.sum()) * p.core_active_w
    return EnergyBreakdown(static_j=static, dynamic_j=dynamic, compute_j=compute)


def enoc_energy(
    trace: EpochTrace,
    mapping: Mapping,
    n_state_transitions: int = 0,
    params: EnergyParams | None = None,
) -> EnergyBreakdown:
    p = params or EnergyParams()
    t = trace.total_s
    static = (p.router_leak_w * mapping.m + p.core_idle_w * mapping.m) * t

    hop_bits = trace.total_hop_bytes * 8.0
    dynamic = hop_bits * (p.router_pj_per_bit + p.link_pj_per_bit) * 1e-12
    dynamic += n_state_transitions * p.state_transition_nj * 1e-9

    compute = float(trace.core_busy_s.sum()) * p.core_active_w
    return EnergyBreakdown(static_j=static, dynamic_j=dynamic, compute_j=compute)
