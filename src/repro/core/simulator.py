"""Epoch-time simulator for FCNN training on ONoC and ENoC — the paper's
Gem5 stand-in (Section 5.1).

Two interconnect backends:

  * ``ONoCBackend``  — WDM/TDM ring (Section 3.1.2): per transition,
    ceil(senders/λ)·B time slots; latency is distance-independent (one
    time-of-flight regardless of hop count), which is why the paper finds
    FM ≈ RRM ≈ ORRM on ONoC.
  * ``ENoCBackend``  — electrical 2-D mesh with XY shortest-path routing,
    2-cycle per-hop routers (Section 5.4), no multicast: a broadcast is a
    sequence of unicasts.  Per transition the time is the max over links of
    serialized traffic plus the average path latency — distance (and hence
    the mapping strategy) matters.

The simulator consumes a Mapping (strategy-placed windows), so all of the
paper's §4 placement effects are visible to the ENoC backend, and the
traffic/occupancy traces feed the energy model.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Protocol

import numpy as np

from .allocation import Mapping, MappingStrategy, map_cores
from .onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    compute_time,
    comm_time,
    period_layer,
    slot_time,
)

__all__ = [
    "TransitionTraffic",
    "EpochTrace",
    "ONoCBackend",
    "ENoCConfig",
    "ENoCBackend",
    "simulate_epoch",
]


@dataclasses.dataclass(frozen=True)
class TransitionTraffic:
    """Data movement out of one period into the next."""

    period: int
    senders: tuple[int, ...]
    receivers: tuple[int, ...]
    bytes_per_sender: float
    comm_s: float                  # backend-computed transition time
    hop_bytes: float = 0.0         # Σ bytes × hops (ENoC); 0 for ONoC
    slots: int = 0                 # TDM slots (ONoC); 0 for ENoC


@dataclasses.dataclass(frozen=True)
class EpochTrace:
    backend: str
    strategy: str
    compute_s: float
    comm_s: float
    transitions: tuple[TransitionTraffic, ...]
    per_period_compute_s: tuple[float, ...]
    core_busy_s: np.ndarray        # per-core active seconds (compute)

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    @property
    def total_bytes(self) -> float:
        return float(
            sum(t.bytes_per_sender * len(t.senders) for t in self.transitions)
        )

    @property
    def total_hop_bytes(self) -> float:
        return float(sum(t.hop_bytes for t in self.transitions))


class _Backend(Protocol):
    name: str

    def transition_time(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic: ...


def _transition_payload_bytes(
    workload: FCNNWorkload, cfg: ONoCConfig, period: int, m_i: int
) -> float:
    """Bytes each sender core pushes out of ``period``."""
    x_i = math.ceil(workload.n(period_layer(workload, period)) / m_i)
    return x_i * workload.batch_size * cfg.bytes_per_value


class ONoCBackend:
    """WDM/TDM ring — Eq. (6) exactly."""

    name = "onoc"

    def transition_time(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic:
        senders = mapping.window(period)
        receivers = mapping.window(period + 1)
        m_i = len(senders)
        payload = _transition_payload_bytes(workload, cfg, period, m_i)
        slots = math.ceil(m_i / cfg.lambda_max)
        t = comm_time(workload, cfg, period, m_i)
        return TransitionTraffic(
            period=period, senders=senders, receivers=receivers,
            bytes_per_sender=payload, comm_s=t, slots=slots,
        )


@dataclasses.dataclass(frozen=True)
class ENoCConfig:
    """Electrical 2-D mesh parameters (paper Section 5.4 + Table 4/5)."""

    hop_cycles: float = 2.0          # per-hop router latency
    link_bytes_per_cycle: float = 16.0  # 128-bit links, 1 flit/cycle
    clock_hz: float = 3.4e9
    channels: int = 4                # 4-channel routers (paper §5.4)

    def link_bandwidth_Bps(self) -> float:
        """Per-channel serialization bandwidth of one directed link."""
        return self.link_bytes_per_cycle * self.clock_hz

    def effective_link_bandwidth_Bps(self) -> float:
        """Drain bandwidth of one directed link: the router's ``channels``
        parallel channels each serialize at ``link_bandwidth_Bps`` (this is
        how the 4-channel routers of §5.4 enter the traffic model).

        Deliberately ENoC-optimistic: real router channels are virtual
        channels sharing one physical link, so crediting them as parallel
        serializers gives ENoC up to ``channels``× the paper's effective
        bandwidth.  The ONoC-vs-ENoC comparisons therefore UNDER-state the
        paper's gaps (Fig. 10 time reduction ~4% here vs 13-21% in the
        paper) — every "ONoC wins" result holds even with this head start.
        Set ``channels=1`` to recover the single-serializer model."""
        return self.link_bandwidth_Bps() * self.channels


class ENoCBackend:
    """2-D mesh, XY shortest-path, unicast-only broadcast."""

    name = "enoc"

    def __init__(self, enoc: ENoCConfig | None = None):
        self.enoc = enoc or ENoCConfig()

    def _grid(self, m: int) -> int:
        return max(1, int(math.ceil(math.sqrt(m))))

    def _xy(self, core: int, side: int) -> tuple[int, int]:
        return core % side, core // side

    def _hops(self, a: int, b: int, side: int) -> int:
        ax, ay = self._xy(a, side)
        bx, by = self._xy(b, side)
        return abs(ax - bx) + abs(ay - by)

    def transition_time(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic:
        """Vectorized XY link-load accumulation.

        Each sender unicasts its payload to every receiver (no multicast).
        Traffic model: per-link serialized occupancy with XY routing; the
        transition completes when the most-loaded link drains at the
        router's aggregate channel bandwidth (``channels`` parallel
        channels per link, §5.4), plus one max-path latency to account
        for the pipeline fill.

        A pair (s, r) traverses the eastbound link (x, y)->(x+1, y) iff
        s is in row y with sx <= x and rx >= x+1 (X-first routing), and the
        northbound link (c, y)->(c, y+1) iff rx == c with ry >= y+1 and
        sy <= y — sender/receiver conditions are independent, so every
        directed link's pair count is a product of two cumulative counts.
        That turns the O(m_i² · hops) Python loop into O(side²) numpy.
        Self-pairs (r == s) can satisfy none of the segment conditions and
        traverse zero hops, so no exclusion term is needed.  Link loads and
        hop_bytes are integer-valued, so count × payload is bit-identical
        to the loop's repeated addition.
        """
        senders = mapping.window(period)
        receivers = mapping.window(period + 1)
        m_i = len(senders)
        payload = _transition_payload_bytes(workload, cfg, period, m_i)
        side = self._grid(mapping.m)

        s = np.asarray(senders, dtype=np.int64)
        r = np.asarray(receivers, dtype=np.int64)
        sx, sy = s % side, s // side
        rx, ry = r % side, r // side

        hops = np.abs(sx[:, None] - rx[None, :]) + np.abs(
            sy[:, None] - ry[None, :])
        hop_bytes = payload * float(hops.sum())
        max_hops = int(hops.max()) if hops.size else 0

        # per-cell occupancy counts
        s_grid = np.zeros((side, side), dtype=np.int64)   # [y, x] senders
        np.add.at(s_grid, (sy, sx), 1)
        r_grid = np.zeros((side, side), dtype=np.int64)   # [x, y] receivers
        np.add.at(r_grid, (rx, ry), 1)
        s_per_row = s_grid.sum(axis=1)                    # [y]
        r_per_col = r_grid.sum(axis=1)                    # [x]

        max_pairs = 0
        if side > 1:
            # horizontal links in row y at x (east: x->x+1, west: x+1->x)
            s_le_x = np.cumsum(s_grid, axis=1)            # sx <= x in row y
            s_ge_x = s_grid[:, ::-1].cumsum(axis=1)[:, ::-1]
            r_le_c = np.cumsum(r_per_col)                 # rx <= x (any row)
            r_ge_c = r_per_col[::-1].cumsum()[::-1]
            east = s_le_x[:, :-1] * r_ge_c[None, 1:]
            west = s_ge_x[:, 1:] * r_le_c[None, :-1]
            # vertical links in column c at y (north: y->y+1, south: y+1->y)
            r_le_y = np.cumsum(r_grid, axis=1)            # rx==c, ry <= y
            r_ge_y = r_grid[:, ::-1].cumsum(axis=1)[:, ::-1]
            s_le_row = np.cumsum(s_per_row)               # sy <= y (any col)
            s_ge_row = s_per_row[::-1].cumsum()[::-1]
            north = r_ge_y[:, 1:] * s_le_row[None, :-1]
            south = r_le_y[:, :-1] * s_ge_row[None, 1:]
            max_pairs = max(int(east.max()), int(west.max()),
                            int(north.max()), int(south.max()))

        bw = self.enoc.effective_link_bandwidth_Bps()
        drain = (max_pairs * payload / bw) if max_pairs else 0.0
        latency = max_hops * self.enoc.hop_cycles / self.enoc.clock_hz
        return TransitionTraffic(
            period=period, senders=senders, receivers=receivers,
            bytes_per_sender=payload, comm_s=drain + latency,
            hop_bytes=hop_bytes,
        )

    def transition_time_reference(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic:
        """Original per-pair Python-loop implementation — kept as the oracle
        the vectorized ``transition_time`` is validated against
        (tests/test_simulator_energy.py asserts bit-identical comm_s and
        hop_bytes)."""
        senders = mapping.window(period)
        receivers = mapping.window(period + 1)
        m_i = len(senders)
        payload = _transition_payload_bytes(workload, cfg, period, m_i)
        side = self._grid(mapping.m)

        link_load: dict[tuple[int, int, int, int], float] = {}
        hop_bytes = 0.0
        max_hops = 0
        for s in senders:
            for r in receivers:
                if r == s:
                    continue
                h = self._hops(s, r, side)
                hop_bytes += payload * h
                max_hops = max(max_hops, h)
                # accumulate along the XY path
                sx, sy = self._xy(s, side)
                rx, ry = self._xy(r, side)
                x, y = sx, sy
                while x != rx:
                    nx = x + (1 if rx > x else -1)
                    link_load[(x, y, nx, y)] = link_load.get((x, y, nx, y), 0.0) + payload
                    x = nx
                while y != ry:
                    ny = y + (1 if ry > y else -1)
                    link_load[(x, y, x, ny)] = link_load.get((x, y, x, ny), 0.0) + payload
                    y = ny
        bw = self.enoc.effective_link_bandwidth_Bps()
        drain = (max(link_load.values()) / bw) if link_load else 0.0
        latency = max_hops * self.enoc.hop_cycles / self.enoc.clock_hz
        return TransitionTraffic(
            period=period, senders=senders, receivers=receivers,
            bytes_per_sender=payload, comm_s=drain + latency,
            hop_bytes=hop_bytes,
        )


def simulate_epoch(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    mapping: Mapping | None = None,
    strategy: MappingStrategy | str = MappingStrategy.FM,
    cores_per_period: list[int] | None = None,
    backend: _Backend | None = None,
    faults=None,
) -> EpochTrace:
    """Simulate one epoch: per-period compute + per-transition comm.

    Communication transitions follow Eq. (6)'s convention: there are
    exactly 2l−2 of them, at periods i ∈ {1, …, 2l−1} \\ {l}.  Period l
    (the forward→backward turnaround at the output layer) keeps its data
    in place, and period 2l ends the epoch, so neither sends.  On ONoC,
    period 1's hand-off is additionally charged as zero time — Eq. (6)
    sets g(m_1) = 0, folding it into Period-0 input loading — though its
    traffic is still recorded; on ENoC nothing is free and period 1 pays
    like every other transition.

    ``faults`` (optional) is a degradation model, typically
    ``runtime.faults.EpochFaults``, with three hooks:
    ``apply_config(cfg)`` (wavelength loss shrinks the usable comb),
    ``compute_scale(period)`` (straggling cores inflate compute), and
    ``apply_transition(traffic, period)`` (degraded links inflate drain).
    Degradation never changes *what* is scheduled, only its price; the
    ONoC period-1 free hand-off stays free (Eq. 6 is a scheduling
    convention, not a bandwidth property).
    """
    backend = backend or ONoCBackend()
    if faults is not None:
        cfg = faults.apply_config(cfg)
    if mapping is None:
        mapping = map_cores(workload, cfg, strategy, cores_per_period)
    l = workload.l

    per_period_compute: list[float] = []
    busy = np.zeros(mapping.m, dtype=np.float64)
    for i in range(1, 2 * l + 1):
        m_i = len(mapping.window(i))
        f = compute_time(workload, cfg, i, m_i)
        if faults is not None:
            f *= faults.compute_scale(i)
        per_period_compute.append(f)
        busy[list(mapping.window(i))] += f

    transitions: list[TransitionTraffic] = []
    comm_total = 0.0
    for i in range(1, 2 * l):   # period 2l is excluded by the range itself
        if i == l:
            continue
        tr = backend.transition_time(workload, cfg, i, mapping)
        if faults is not None:
            tr = faults.apply_transition(tr, i)
        if backend.name == "onoc" and i == 1:
            # Eq. (6): g(m_1) = 0 — the ONoC model folds the period-1
            # hand-off into Period 0 loading.  Record traffic, zero time.
            tr = dataclasses.replace(tr, comm_s=0.0)
        transitions.append(tr)
        comm_total += tr.comm_s

    return EpochTrace(
        backend=backend.name,
        strategy=mapping.strategy.value,
        compute_s=float(sum(per_period_compute)),
        comm_s=float(comm_total),
        transitions=tuple(transitions),
        per_period_compute_s=tuple(per_period_compute),
        core_busy_s=busy,
    )
