"""Epoch-time simulator for FCNN training on ONoC and ENoC — the paper's
Gem5 stand-in (Section 5.1).

Two interconnect backends:

  * ``ONoCBackend``  — WDM/TDM ring (Section 3.1.2): per transition,
    ceil(senders/λ)·B time slots; latency is distance-independent (one
    time-of-flight regardless of hop count), which is why the paper finds
    FM ≈ RRM ≈ ORRM on ONoC.
  * ``ENoCBackend``  — electrical 2-D mesh with XY shortest-path routing,
    2-cycle per-hop routers (Section 5.4), no multicast: a broadcast is a
    sequence of unicasts.  Per transition the time is the max over links of
    serialized traffic plus the average path latency — distance (and hence
    the mapping strategy) matters.

The simulator consumes a Mapping (strategy-placed windows), so all of the
paper's §4 placement effects are visible to the ENoC backend, and the
traffic/occupancy traces feed the energy model.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Protocol

import numpy as np

from .allocation import Mapping, MappingStrategy, map_cores
from .onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    compute_time,
    comm_time,
    period_layer,
    slot_time,
)

__all__ = [
    "TransitionTraffic",
    "EpochTrace",
    "ONoCBackend",
    "ENoCConfig",
    "ENoCBackend",
    "simulate_epoch",
]


@dataclasses.dataclass(frozen=True)
class TransitionTraffic:
    """Data movement out of one period into the next."""

    period: int
    senders: tuple[int, ...]
    receivers: tuple[int, ...]
    bytes_per_sender: float
    comm_s: float                  # backend-computed transition time
    hop_bytes: float = 0.0         # Σ bytes × hops (ENoC); 0 for ONoC
    slots: int = 0                 # TDM slots (ONoC); 0 for ENoC


@dataclasses.dataclass(frozen=True)
class EpochTrace:
    backend: str
    strategy: str
    compute_s: float
    comm_s: float
    transitions: tuple[TransitionTraffic, ...]
    per_period_compute_s: tuple[float, ...]
    core_busy_s: np.ndarray        # per-core active seconds (compute)

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s

    @property
    def total_bytes(self) -> float:
        return float(
            sum(t.bytes_per_sender * len(t.senders) for t in self.transitions)
        )

    @property
    def total_hop_bytes(self) -> float:
        return float(sum(t.hop_bytes for t in self.transitions))


class _Backend(Protocol):
    name: str

    def transition_time(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic: ...


def _transition_payload_bytes(
    workload: FCNNWorkload, cfg: ONoCConfig, period: int, m_i: int
) -> float:
    """Bytes each sender core pushes out of ``period``."""
    x_i = math.ceil(workload.n(period_layer(workload, period)) / m_i)
    return x_i * workload.batch_size * cfg.bytes_per_value


class ONoCBackend:
    """WDM/TDM ring — Eq. (6) exactly."""

    name = "onoc"

    def transition_time(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic:
        senders = mapping.window(period)
        receivers = mapping.window(period + 1)
        m_i = len(senders)
        payload = _transition_payload_bytes(workload, cfg, period, m_i)
        slots = math.ceil(m_i / cfg.lambda_max)
        t = comm_time(workload, cfg, period, m_i)
        return TransitionTraffic(
            period=period, senders=senders, receivers=receivers,
            bytes_per_sender=payload, comm_s=t, slots=slots,
        )


@dataclasses.dataclass(frozen=True)
class ENoCConfig:
    """Electrical 2-D mesh parameters (paper Section 5.4 + Table 4/5)."""

    hop_cycles: float = 2.0          # per-hop router latency
    link_bytes_per_cycle: float = 16.0  # 128-bit links, 1 flit/cycle
    clock_hz: float = 3.4e9
    channels: int = 4                # 4-channel routers (paper)

    def link_bandwidth_Bps(self) -> float:
        return self.link_bytes_per_cycle * self.clock_hz


class ENoCBackend:
    """2-D mesh, XY shortest-path, unicast-only broadcast."""

    name = "enoc"

    def __init__(self, enoc: ENoCConfig | None = None):
        self.enoc = enoc or ENoCConfig()

    def _grid(self, m: int) -> int:
        return max(1, int(math.ceil(math.sqrt(m))))

    def _xy(self, core: int, side: int) -> tuple[int, int]:
        return core % side, core // side

    def _hops(self, a: int, b: int, side: int) -> int:
        ax, ay = self._xy(a, side)
        bx, by = self._xy(b, side)
        return abs(ax - bx) + abs(ay - by)

    def transition_time(
        self,
        workload: FCNNWorkload,
        cfg: ONoCConfig,
        period: int,
        mapping: Mapping,
    ) -> TransitionTraffic:
        senders = mapping.window(period)
        receivers = mapping.window(period + 1)
        m_i = len(senders)
        payload = _transition_payload_bytes(workload, cfg, period, m_i)
        side = self._grid(mapping.m)

        # Each sender unicasts its payload to every receiver (no multicast).
        # Traffic model: per-link serialized occupancy with XY routing; the
        # transition completes when the most-loaded link drains, plus one
        # max-path latency to account for the pipeline fill.
        link_load: dict[tuple[int, int, int, int], float] = {}
        hop_bytes = 0.0
        max_hops = 0
        for s in senders:
            for r in receivers:
                if r == s:
                    continue
                h = self._hops(s, r, side)
                hop_bytes += payload * h
                max_hops = max(max_hops, h)
                # accumulate along the XY path
                sx, sy = self._xy(s, side)
                rx, ry = self._xy(r, side)
                x, y = sx, sy
                while x != rx:
                    nx = x + (1 if rx > x else -1)
                    link_load[(x, y, nx, y)] = link_load.get((x, y, nx, y), 0.0) + payload
                    x = nx
                while y != ry:
                    ny = y + (1 if ry > y else -1)
                    link_load[(x, y, x, ny)] = link_load.get((x, y, x, ny), 0.0) + payload
                    y = ny
        bw = self.enoc.link_bandwidth_Bps()
        drain = (max(link_load.values()) / bw) if link_load else 0.0
        latency = max_hops * self.enoc.hop_cycles / self.enoc.clock_hz
        return TransitionTraffic(
            period=period, senders=senders, receivers=receivers,
            bytes_per_sender=payload, comm_s=drain + latency,
            hop_bytes=hop_bytes,
        )


def simulate_epoch(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    mapping: Mapping | None = None,
    strategy: MappingStrategy | str = MappingStrategy.FM,
    cores_per_period: list[int] | None = None,
    backend: _Backend | None = None,
) -> EpochTrace:
    """Simulate one epoch: per-period compute + per-transition comm.

    Communication transitions follow Eq. (6)'s convention: periods l and 2l
    send nothing; period 1's hand-off is charged as comm of period... none
    (Eq. 6 zeroes it; the traffic is still recorded with comm_s as computed
    by the backend for ENoC, where nothing is free).
    """
    backend = backend or ONoCBackend()
    if mapping is None:
        mapping = map_cores(workload, cfg, strategy, cores_per_period)
    l = workload.l

    per_period_compute: list[float] = []
    busy = np.zeros(mapping.m, dtype=np.float64)
    for i in range(1, 2 * l + 1):
        m_i = len(mapping.window(i))
        f = compute_time(workload, cfg, i, m_i)
        per_period_compute.append(f)
        busy[list(mapping.window(i))] += f

    transitions: list[TransitionTraffic] = []
    comm_total = 0.0
    for i in range(1, 2 * l):
        if i in (l, 2 * l):
            continue
        tr = backend.transition_time(workload, cfg, i, mapping)
        if backend.name == "onoc" and i == 1:
            # Eq. (6): g(m_1) = 0 — the ONoC model folds the period-1
            # hand-off into Period 0 loading.  Record traffic, zero time.
            tr = dataclasses.replace(tr, comm_s=0.0)
        transitions.append(tr)
        comm_total += tr.comm_s

    return EpochTrace(
        backend=backend.name,
        strategy=mapping.strategy.value,
        compute_s=float(sum(per_period_compute)),
        comm_s=float(comm_total),
        transitions=tuple(transitions),
        per_period_compute_s=tuple(per_period_compute),
        core_busy_s=busy,
    )
