"""Traditional parallel-computing baselines the paper compares against
(Section 5.3):

  FGP — Finest-Grained Parallel [28]: one neuron per core,
        m_i = min(n_i, φ·m).
  FNP — Fixed Number Parallel [29]: a fixed core count (200 in the paper)
        for every period, m_i = min(fixed, n_i, φ·m).
"""

from __future__ import annotations

from .onoc_model import FCNNWorkload, ONoCConfig

__all__ = ["fgp_cores", "fnp_cores"]


def fgp_cores(workload: FCNNWorkload, cfg: ONoCConfig) -> list[int]:
    cap = int(cfg.phi * cfg.m)
    return [min(workload.n(i), cap) for i in range(1, workload.l + 1)]


def fnp_cores(
    workload: FCNNWorkload, cfg: ONoCConfig, fixed: int = 200
) -> list[int]:
    cap = int(cfg.phi * cfg.m)
    return [min(fixed, workload.n(i), cap) for i in range(1, workload.l + 1)]
