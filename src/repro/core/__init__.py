"""The paper's contribution: ONoC-aware optimal core allocation and mapping
for FCNN training (Dai, Chen, Zhang, Huang — 2021), plus its adaptation to
TPU meshes (planner)."""

from .onoc_model import (  # noqa: F401
    FCNNWorkload,
    ONoCConfig,
    PeriodCosts,
    brute_force_optimal_cores,
    comm_time,
    compute_time,
    epoch_time,
    optimal_cores,
    optimal_cores_continuous,
    optimal_epoch_time,
    prediction_error,
    theta,
)
from .allocation import (  # noqa: F401
    Mapping,
    MappingStrategy,
    expected_reuse,
    map_cores,
    neuron_assignment,
    reuse_counts,
)
from .analyses import (  # noqa: F401
    StrategyReport,
    analyze_mapping,
    hotspot_consecutive_periods,
    insertion_loss_db,
    max_memory_requirement_bytes,
    max_path_length,
    memory_per_core_bytes,
    state_transitions,
)
from .wavelength import assign_wavelengths, schedule_epoch  # noqa: F401
from .simulator import (  # noqa: F401
    ENoCBackend,
    ENoCConfig,
    EpochTrace,
    ONoCBackend,
    simulate_epoch,
)
from .energy import EnergyBreakdown, EnergyParams, enoc_energy, onoc_energy  # noqa: F401
from .baselines import fgp_cores, fnp_cores  # noqa: F401
