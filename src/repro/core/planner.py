"""The ONoC allocator carried onto TPU meshes — the paper's technique as a
first-class distribution feature (DESIGN.md §4).

``plan_fcnn`` is the faithful path: per-period Lemma-1 core counts snapped
to mesh-feasible sharding degrees, with the chosen mapping strategy
determining the device ring order.

``plan_transformer`` extends the same cost model to a transformer block's
GEMM "periods" (qkv/o/gate/up/down — and expert FFNs with an all-to-all
comm term for MoE): for each candidate TP degree it evaluates
  compute ≈ FLOPs / (d · peak)        (the paper's f, Eq. 5)
  comm    ≈ ag_bytes(d)/link + rs_bytes(d)/link     (g, Eq. 6 with the
            all-gather ring-step model replacing WDM slot counting)
and picks the argmin — i.e. Lemma 1 evaluated on the discrete feasible set
{1, model, model·data, ...} instead of [1, φm] (the mesh can only shard at
factorable degrees; DESIGN.md §2 records this assumption change).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Sequence

from repro.core.onoc_model import FCNNWorkload, ONoCConfig, optimal_cores
from repro.core.allocation import MappingStrategy, map_cores, Mapping

__all__ = ["TPUTarget", "PeriodPlan", "FCNNPlan", "plan_fcnn",
           "feasible_degrees", "ring_mesh_axes", "plan_gemm_period"]


@dataclasses.dataclass(frozen=True)
class TPUTarget:
    """v5e-class hardware constants (per chip)."""

    peak_flops: float = 197e12        # bf16
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s per link
    hbm_bytes: float = 16e9
    vmem_bytes: float = 128e6


@dataclasses.dataclass(frozen=True)
class PeriodPlan:
    period: int
    onoc_cores: int          # Lemma-1 m_i* (the paper's answer)
    degree: int              # mesh-feasible sharding degree (the TPU answer)
    axes: tuple[str, ...]    # mesh axes realizing the degree
    compute_s: float
    comm_s: float


@dataclasses.dataclass(frozen=True)
class FCNNPlan:
    periods: tuple[PeriodPlan, ...]
    mapping: Mapping
    strategy: str

    @property
    def degrees(self) -> list[int]:
        return [p.degree for p in self.periods]


def feasible_degrees(mesh_axes: dict[str, int]) -> dict[int, tuple[str, ...]]:
    """All sharding degrees expressible as a product of ANY subset of mesh
    axes — not just contiguous runs of the preference order.  A mesh
    {model: 2, data: 3, pod: 2} can realize degree 4 as model×pod; the old
    prefix/suffix enumeration missed it and silently snapped plans to a
    worse degree.

    When several subsets yield the same degree, the recorded axes prefer
    fewer axes, breaking ties by the canonical order: "model" first
    (highest-bandwidth contiguous ring), then "data", then "pod"."""
    order = [a for a in ("model", "data", "pod") if a in mesh_axes]
    order += [a for a in mesh_axes if a not in order]
    out: dict[int, tuple[str, ...]] = {1: ()}
    for size in range(1, len(order) + 1):
        for axes in itertools.combinations(order, size):
            prod = math.prod(mesh_axes[a] for a in axes)
            out.setdefault(prod, axes)
    return out


def ring_mesh_axes(n_devices: int, prefix: str = "ring") -> dict[str, int]:
    """Mesh axes whose subset products cover EVERY divisor of n_devices —
    one axis per prime factor (with multiplicity), so ``feasible_degrees``
    can realize any divisor.  This is the planning view of the execution
    engine's device ring (exec/program.py): a ring of n cores can activate
    any m | n of them with a uniform chunk layout."""
    if n_devices < 1:
        raise ValueError("n_devices >= 1")
    axes: dict[str, int] = {}
    rem, p, k = n_devices, 2, 0
    while rem > 1:
        while rem % p == 0:
            axes[f"{prefix}{k}"] = p
            rem //= p
            k += 1
        p += 1 if p == 2 else 2
    return axes or {f"{prefix}0": 1}


def _snap_degree(target: int, feas: dict[int, tuple[str, ...]]) -> int:
    """Nearest feasible degree in log space (ratio-symmetric)."""
    return min(feas, key=lambda d: abs(math.log(max(d, 1) / max(target, 1))))


def plan_fcnn(
    workload: FCNNWorkload,
    onoc_cfg: ONoCConfig,
    mesh_axes: dict[str, int],
    strategy: MappingStrategy | str = MappingStrategy.ORRM,
    refine_plateau: bool = True,
) -> FCNNPlan:
    """Paper-faithful plan: Lemma-1 core counts snapped to the mesh."""
    from repro.core.onoc_model import compute_time, comm_time

    stars = optimal_cores(workload, onoc_cfg, refine_plateau=refine_plateau)
    feas = feasible_degrees(mesh_axes)
    n_dev = 1
    for v in mesh_axes.values():
        n_dev *= v

    periods = []
    snapped = []
    for i, m_star in enumerate(stars, start=1):
        n_i = workload.n(i)
        cap = min(n_i, n_dev)
        # the paper's even-mapping constraint (Eq. 4 ceil becomes exact):
        # only degrees that divide n_i are eligible
        eligible = {d: ax for d, ax in feas.items()
                    if d <= cap and n_i % d == 0}
        if not eligible:
            eligible = {1: ()}
        deg = min(eligible,
                  key=lambda d: abs(math.log(d / max(min(m_star, cap), 1))))
        snapped.append(deg)
        periods.append(PeriodPlan(
            period=i, onoc_cores=m_star, degree=deg, axes=feas.get(deg, ()),
            compute_s=compute_time(workload, onoc_cfg, i, m_star),
            comm_s=comm_time(workload, onoc_cfg, i, m_star),
        ))
    mapping = map_cores(workload, onoc_cfg, strategy, stars)
    return FCNNPlan(periods=tuple(periods), mapping=mapping,
                    strategy=MappingStrategy(strategy).value)


# --------------------------------------------------------------------------
# transformer periods (beyond-paper extension of the same trade-off)
# --------------------------------------------------------------------------

def plan_gemm_period(
    flops: float,
    act_bytes_in: float,
    act_bytes_out: float,
    mesh_axes: dict[str, int],
    tpu: TPUTarget = TPUTarget(),
    all_to_all_bytes: float = 0.0,
) -> tuple[int, tuple[str, ...], dict[int, float]]:
    """Pick the TP degree for one GEMM 'period'.

    Sharding a GEMM's output dim at degree d:
      compute ≈ flops / (d · peak)
      comm    ≈ all-gather of the output into the next period's cores:
                act_bytes_out · (d-1)/d / ici  (+ the BP reduce-scatter,
                same volume — the paper's B_i + B_{2l-i+1} pairing)
      a2a     ≈ all_to_all_bytes/d / ici (MoE dispatch, if any)
    Returns (degree, axes, per-degree cost table)."""
    feas = feasible_degrees(mesh_axes)
    costs: dict[int, float] = {}
    for d, axes in feas.items():
        compute = flops / (d * tpu.peak_flops)
        ag = act_bytes_out * (d - 1) / max(d, 1) / tpu.ici_bw
        rs = act_bytes_in * (d - 1) / max(d, 1) / tpu.ici_bw
        a2a = all_to_all_bytes / max(d, 1) / tpu.ici_bw
        costs[d] = compute + ag + rs + a2a
    best = min(costs, key=costs.get)
    return best, feas[best], costs
