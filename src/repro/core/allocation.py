"""Core allocation on the ONoC ring — the paper's Section 4.

Three mapping strategies place the m_i* cores of each period on the ring:

  FM   (Fixed Mapping):           period i gets cores [1 .. m_i*]
  RRM  (Round-Robin Mapping):     period i starts after period i-1's last core
  ORRM (Overlapped Round-Robin):  RRM but reusing r_i cores between adjacent
                                  periods (Algorithm 1, Eqs. 16-18)

A mapping is represented two ways:
  * ``windows``: per FP period, the ordered list of ring core ids (0-based),
  * ``M``: the paper's mapping matrix — M[i][j] = core id of the j-th neuron
    of layer i (a dict of arrays; the paper's 0/1 tensor M(i,j,k) is sparse
    one-hot over k, we store the argmax).

BP periods reuse the FP windows via the data-locality constraint (Eq. 11).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Sequence

import numpy as np

from .onoc_model import FCNNWorkload, ONoCConfig, optimal_cores

__all__ = [
    "MappingStrategy",
    "Mapping",
    "expected_reuse",
    "reuse_counts",
    "map_cores",
    "neuron_assignment",
]


class MappingStrategy(str, enum.Enum):
    FM = "fm"
    RRM = "rrm"
    ORRM = "orrm"


@dataclasses.dataclass(frozen=True)
class Mapping:
    """A complete neuron→core placement for one epoch."""

    strategy: MappingStrategy
    m: int                                  # ring size
    cores_per_period: tuple[int, ...]       # m_i* for FP periods 1..l
    windows: tuple[tuple[int, ...], ...]    # per FP period, ring core ids
    reuse: tuple[int, ...]                  # r_i per FP period (r_1 = 0)

    @property
    def l(self) -> int:  # noqa: E743
        return len(self.windows)

    def window(self, period: int) -> tuple[int, ...]:
        """Ring core ids for any period 1..2l (Eq. 11 ties BP to FP)."""
        l = self.l
        if 1 <= period <= l:
            return self.windows[period - 1]
        if l + 1 <= period <= 2 * l:
            return self.windows[2 * l - period]
        raise ValueError(f"period out of range: {period}")

    def neuron_core(self, layer: int, j: int) -> int:
        """Core id of neuron j (0-based) of layer ``layer`` (1-based)."""
        w = self.windows[layer - 1]
        return w[j % len(w)]

    def active_cores(self) -> set[int]:
        out: set[int] = set()
        for w in self.windows:
            out.update(w)
        return out


def expected_reuse(cores_per_period: Sequence[int], m: int) -> float:
    """E[r], Eq. (16)."""
    l = len(cores_per_period)
    total = sum(cores_per_period)
    if total <= m or l <= 1:
        return 0.0
    return (total - m) / (l - 1)


def reuse_counts(cores_per_period: Sequence[int], m: int) -> list[int]:
    """r_i, Eq. (17):  r_1 = 0;
    r_i = min(round(E[r]), m_{i-1}* - r_{i-1}, m_i*)  for i in [2, l]."""
    e_r = expected_reuse(cores_per_period, m)
    r = [0]
    for i in range(1, len(cores_per_period)):
        r_i = min(
            int(round(e_r)),
            cores_per_period[i - 1] - r[i - 1],
            cores_per_period[i],
        )
        r.append(max(0, r_i))
    return r


def map_cores(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    strategy: MappingStrategy | str = MappingStrategy.ORRM,
    cores_per_period: Sequence[int] | None = None,
) -> Mapping:
    """Place the per-period core counts on the ring (paper Section 4.1).

    ``cores_per_period`` defaults to the Lemma-1 optimum.
    """
    strategy = MappingStrategy(strategy)
    if cores_per_period is None:
        cores_per_period = optimal_cores(workload, cfg)
    cores_per_period = [int(c) for c in cores_per_period]
    l = workload.l
    if len(cores_per_period) != l:
        raise ValueError(f"need {l} core counts, got {len(cores_per_period)}")
    if max(cores_per_period) > cfg.m:
        raise ValueError("a period requests more cores than the ring has")

    m = cfg.m
    windows: list[tuple[int, ...]] = []

    if strategy is MappingStrategy.FM:
        reuse = [0] * l
        for m_i in cores_per_period:
            windows.append(tuple(range(m_i)))
        # FM's reuse between adjacent periods is min(m_i, m_{i+1}) by
        # construction; the ``reuse`` field reports the ORRM-style r_i
        # (planned extra reuse), which FM does not use.
    elif strategy is MappingStrategy.RRM:
        reuse = [0] * l
        nxt = 0
        for m_i in cores_per_period:
            windows.append(tuple((nxt + k) % m for k in range(m_i)))
            nxt = (nxt + m_i) % m
    else:  # ORRM, Algorithm 1
        reuse = reuse_counts(cores_per_period, m)
        start = 0  # id_1 = 1 in the paper's 1-based indexing
        for i, m_i in enumerate(cores_per_period):
            if i > 0:
                # id_i = id_{i-1} + (m_{i-1}* - r_i)   (Eq. 18, telescoped)
                start = (start + cores_per_period[i - 1] - reuse[i]) % m
            windows.append(tuple((start + k) % m for k in range(m_i)))

    return Mapping(
        strategy=strategy,
        m=m,
        cores_per_period=tuple(cores_per_period),
        windows=tuple(windows),
        reuse=tuple(reuse),
    )


def neuron_assignment(workload: FCNNWorkload, mapping: Mapping) -> dict[int, np.ndarray]:
    """The paper's mapping matrix M, densified: layer -> array of core ids.

    Neurons are mapped evenly: neuron j of layer i goes to window[j mod m_i]
    (Algorithm 1 lines 3 & 8 distribute evenly; round-robin over the window
    yields |count_k - count_k'| <= 1 for all cores k, k' in the window).
    """
    out: dict[int, np.ndarray] = {}
    for layer in range(1, workload.l + 1):
        w = np.asarray(mapping.windows[layer - 1], dtype=np.int64)
        n_i = workload.n(layer)
        out[layer] = w[np.arange(n_i) % len(w)]
    return out
