"""The paper's fine-grained parallel computing model (Section 3).

One training epoch of an (l+1)-layer FCNN is divided into 2l *periods*:
Period 1..l    = forward propagation through layers 1..l,
Period l+1..2l = back propagation (period i touches layer 2l-i+1).

Everything here is the paper's math:

  Eq. (4)  X_i        neurons per core in period i
  Eq. (5)  f(m_i)     per-core compute time of period i
  Eq. (6)  g(m_i)     WDM/TDM communication time of period i
  Eq. (7)  T          epoch time
  Lemma 1  m_i*       closed-form optimal core count per period
  Theorem 1 T*        minimal epoch time

On B_i and Lemma 1 (a modelling note recorded in DESIGN.md §6): the paper
defines B_i as "the amount of time for one core in Period i to complete the
communications" and then differentiates T treating B_i as a constant.  A
sender's time has two parts:

  B_i(m_i) = B_setup + payload(X_i · mu)            (this module's model)

where B_setup is the fixed per-transmission cost (RWA/router configuration,
SRAM front/back-end access, E/O-O/E conversion pipeline fill) and
payload(X_i·mu) is the wire + per-flit time of the X_i = ceil(n_i/m_i)
neuron outputs over the mu-sample batch.  In the continuous relaxation,

  g(m) = (m/λ)(B_setup + p·n·mu/m) = m·B_setup/λ + p·n·mu/λ,

so the payload term is *invariant in m* (the total broadcast volume is
fixed) and drops from dT/dm — Lemma 1 therefore holds exactly with
B_i := B_setup.  The discrete simulator keeps the full staircase
ceil(m/λ)·B_i(m_i); the gap between the two is pure discretization, which
is what produces the small nonzero APE the paper reports in Table 7.

Eq. (6) sets g = 0 for periods 1, l and 2l.  With g(m_1) = 0, dT/dm_1 < 0
everywhere and Lemma 1's Case I degenerates to the clamp
m_1* = min(φ·m, n_1) — which is exactly what every row of the paper's
Table 10 shows (the first entry is always min(m, n_1) = 1000).  We follow
that operative rule; the published Case-I formula with B_1 in the
denominator is superseded by Eq. (6)'s own convention.

Units: C is core compute capacity in MAC/s; alpha/beta are MAC counts;
B_i is seconds.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "ONoCConfig",
    "FCNNWorkload",
    "PeriodCosts",
    "compute_time",
    "comm_time",
    "slot_time",
    "epoch_time",
    "theta",
    "optimal_cores",
    "optimal_cores_continuous",
    "optimal_epoch_time",
    "brute_force_optimal_cores",
    "prediction_error",
    "period_layer",
    "neurons_per_core",
]


@dataclasses.dataclass(frozen=True)
class ONoCConfig:
    """Platform parameters (paper Tables 4 & 5)."""

    m: int = 1000                 # total cores on the ring
    lambda_max: int = 64          # available wavelengths (8 or 64 in the paper)
    C: float = 3.0e9              # MACs/s per core (6 GFLOPS peak => 3 GMAC/s)
    phi: float = 1.0              # utilization cap, Eq. (9) (paper sets phi=1)
    bandwidth_bps: float = 40e9   # per-wavelength bandwidth (Table 5)
    bytes_per_value: int = 4      # FP32 parameters
    core_hz: float = 3.4e9        # core frequency (Table 4)
    # Fixed per-transmission setup: RWA + router config + SRAM front/back end
    # + EO/OE pipeline fill.  103 core cycles ≈ 30.3 ns, calibrated so the
    # Lemma-1 optimum for NN1 layer 2 at (BS=1, λ=8) reproduces the paper's
    # Table 10 value of 257 cores (see DESIGN.md §6).
    setup_cycles: float = 103.0
    # Per-flit pipeline overheads (Table 5), cycles at core_hz.
    oe_eo_cycles: float = 1.0     # OE/EO delay, 1 cycle/flit
    tof_cycles: float = 1.0       # time of flight, 1 cycle/flit
    serialization_cycles: float = 2.0  # serialization, 2 cycles/flit
    flit_bytes: int = 16          # 16 bytes/flit (Section 5.4)
    sram_latency_cycles: float = 10.0  # distributed SRAM access (Table 4)
    d_input_s: float = 0.0        # Period-0 load time (constant w.r.t. m_i)
    zeta_s: float = 0.0           # per-period extra delay (constant)

    @property
    def setup_time_s(self) -> float:
        return self.setup_cycles / self.core_hz

    def payload_time_s(self, n_values: int) -> float:
        """Wire + per-flit pipeline time for n_values parameters."""
        payload_bytes = n_values * self.bytes_per_value
        n_flits = math.ceil(payload_bytes / self.flit_bytes)
        wire = payload_bytes * 8.0 / self.bandwidth_bps
        per_flit = (
            self.oe_eo_cycles
            + self.tof_cycles
            + self.serialization_cycles
            + self.sram_latency_cycles
        ) / self.core_hz
        return wire + n_flits * per_flit


@dataclasses.dataclass(frozen=True)
class FCNNWorkload:
    """An FCNN instance + training-batch description.

    ``layer_sizes`` = [n_0, n_1, ..., n_l]  (n_0 = input layer).
    ``batch_size``  = mu, samples per training epoch in the paper's model.

    alpha_i : MACs per neuron in FP period i over all samples — one MAC per
              incoming connection per sample plus the activation (one
              MAC-equivalent): alpha_i = mu * (n_{i-1} + 1).
    beta_i  : MAC-equivalents per connection weight-update in BP period i
              over all samples (gradient accumulation over mu samples,
              Eq. (2), plus the update, Eq. (3)): beta = mu + 1.
    """

    layer_sizes: Sequence[int]
    batch_size: int = 1

    def __post_init__(self) -> None:
        if len(self.layer_sizes) < 2:
            raise ValueError("an FCNN needs at least input and output layers")
        if any(n <= 0 for n in self.layer_sizes):
            raise ValueError(f"layer sizes must be positive: {self.layer_sizes}")
        if self.batch_size < 1:
            raise ValueError("batch_size >= 1")

    @property
    def l(self) -> int:  # noqa: E743 — paper notation
        return len(self.layer_sizes) - 1

    def n(self, layer: int) -> int:
        return int(self.layer_sizes[layer])

    def alpha(self, i: int) -> float:
        if not 1 <= i <= self.l:
            raise ValueError(f"FP period out of range: {i}")
        return float(self.batch_size) * (self.n(i - 1) + 1.0)

    def beta(self, i: int) -> float:
        if not self.l + 1 <= i <= 2 * self.l:
            raise ValueError(f"BP period out of range: {i}")
        return float(self.batch_size) + 1.0


def period_layer(workload: FCNNWorkload, i: int) -> int:
    """Layer touched by period i (paper Section 3.1)."""
    l = workload.l
    if 1 <= i <= l:
        return i
    if l + 1 <= i <= 2 * l:
        return 2 * l - i + 1
    raise ValueError(f"period out of range: {i} (l={l})")


def _neurons_in_period(workload: FCNNWorkload, i: int) -> int:
    return workload.n(period_layer(workload, i))


def neurons_per_core(workload: FCNNWorkload, i: int, m_i: int) -> int:
    """X_i, Eq. (4)."""
    if m_i < 1:
        raise ValueError("m_i >= 1")
    return math.ceil(_neurons_in_period(workload, i) / m_i)


def compute_time(workload: FCNNWorkload, cfg: ONoCConfig, i: int, m_i: int) -> float:
    """f(m_i), Eq. (5) — seconds of compute on each of the m_i cores."""
    x_i = neurons_per_core(workload, i, m_i)
    l = workload.l
    if 1 <= i <= l:
        return workload.alpha(i) * x_i / cfg.C
    # BP: each neuron updates the weights of its connections to the previous
    # layer (n_{2l-i} of them) plus its bias — (n_{2l-i} + 1) updates.
    n_prev = workload.n(2 * l - i)
    return workload.beta(i) * x_i * (n_prev + 1.0) / cfg.C


def slot_time(workload: FCNNWorkload, cfg: ONoCConfig, i: int, m_i: int) -> float:
    """B_i(m_i) — seconds for one sender in period i (setup + payload)."""
    x_i = neurons_per_core(workload, i, m_i)
    return cfg.setup_time_s + cfg.payload_time_s(x_i * workload.batch_size)


def comm_time(workload: FCNNWorkload, cfg: ONoCConfig, i: int, m_i: int) -> float:
    """g(m_i), Eq. (6): ceil(m_i/λ)·B_i, zero for periods 1, l and 2l."""
    l = workload.l
    if i in (1, l, 2 * l):
        return 0.0
    slots = math.ceil(m_i / cfg.lambda_max)
    return slots * slot_time(workload, cfg, i, m_i)


@dataclasses.dataclass(frozen=True)
class PeriodCosts:
    period: int
    layer: int
    m: int
    compute_s: float
    comm_s: float

    @property
    def total_s(self) -> float:
        return self.compute_s + self.comm_s


def epoch_time(
    workload: FCNNWorkload, cfg: ONoCConfig, cores: Sequence[int]
) -> tuple[float, list[PeriodCosts]]:
    """T, Eq. (7): epoch seconds given per-FP-period core counts.

    ``cores`` has length l (FP periods); BP periods reuse them via the
    data-locality constraint Eq. (11): m_{2l-i+1} = m_i.
    """
    l = workload.l
    if len(cores) != l:
        raise ValueError(f"need {l} per-period core counts, got {len(cores)}")
    per_period: list[PeriodCosts] = []
    total = cfg.d_input_s
    for i in range(1, 2 * l + 1):
        m_i = int(cores[i - 1]) if i <= l else int(cores[2 * l - i])  # Eq. (11)
        _check_constraints(workload, cfg, i, m_i)
        f = compute_time(workload, cfg, i, m_i)
        g = comm_time(workload, cfg, i, m_i)
        total += f + g + cfg.zeta_s
        per_period.append(
            PeriodCosts(period=i, layer=period_layer(workload, i), m=m_i,
                        compute_s=f, comm_s=g)
        )
    return total, per_period


def _check_constraints(
    workload: FCNNWorkload, cfg: ONoCConfig, i: int, m_i: int
) -> None:
    if m_i < 1:
        raise ValueError(f"period {i}: m_i must be >= 1")
    if m_i > cfg.phi * cfg.m + 1e-9:  # Eq. (9)
        raise ValueError(f"period {i}: m_i={m_i} exceeds phi*m={cfg.phi * cfg.m}")
    if m_i > _neurons_in_period(workload, i):  # Eq. (10)
        raise ValueError(
            f"period {i}: m_i={m_i} exceeds neurons "
            f"{_neurons_in_period(workload, i)}"
        )


def theta(workload: FCNNWorkload, cfg: ONoCConfig, i: int) -> float:
    """θ_i = n_i · λ_max · [β_{2l-i+1}(n_{i-1}+1) + α_i]   (Lemma 1)."""
    l = workload.l
    if not 1 <= i <= l:
        raise ValueError("theta is defined for FP periods 1..l")
    n_i = workload.n(i)
    n_prev = workload.n(i - 1)
    beta_bp = workload.beta(2 * l - i + 1)
    return n_i * cfg.lambda_max * (beta_bp * (n_prev + 1.0) + workload.alpha(i))


def optimal_cores_continuous(
    workload: FCNNWorkload, cfg: ONoCConfig
) -> list[float]:
    """Lemma 1's stationary points before ceiling/clamping (FP periods).

    m_i = sqrt(θ_i / (B·C)) with
      B = 0                 for i = 1   (g(m_1) = g(m_2l) = 0 per Eq. (6):
                                         m_1 is unconstrained by comm, so
                                         m_1* = min(φ·m, n_1) — Table 10)
      B = B_i + B_{2l-i+1}  for 1 < i < l
      B = B_{l+1}           for i = l   (g(m_l) = 0; only the BP side pays)
    with B := the fixed setup component (see module docstring).
    """
    l = workload.l
    b_setup = cfg.setup_time_s
    out: list[float] = []
    for i in range(1, l + 1):
        th = theta(workload, cfg, i)
        if l == 1 or i == 1:
            b = 0.0  # no comm attributable to this period's core count
        elif i == l:
            b = b_setup
        else:
            b = 2.0 * b_setup
        if b <= 0.0:
            out.append(float("inf"))
        else:
            out.append(math.sqrt(th / (b * cfg.C)))
    return out


def optimal_cores(
    workload: FCNNWorkload, cfg: ONoCConfig, refine_plateau: bool = False
) -> list[int]:
    """Lemma 1: m_i* = min(ceil(m_i), φ·m, n_i) for FP periods i=1..l.

    ``refine_plateau=True`` applies a closed-form beyond-paper refinement:
    snap m* down to the plateau edge ceil(n_i / X) with X = ceil(n_i/m*).
    Fewer cores with the *same* X_i have identical compute time but strictly
    fewer TDM slots — the continuous relaxation cannot see this because it
    uses X = n/m without the ceiling.  Then compare against the adjacent
    plateau (X-1) edge and keep the cheaper one.  Still O(1) per period, no
    search.
    """
    cont = optimal_cores_continuous(workload, cfg)
    out: list[int] = []
    for i, m_unc in enumerate(cont, start=1):
        cap = min(int(cfg.phi * cfg.m), workload.n(i))  # Eqs. (9), (10)
        m_star = min(
            math.ceil(m_unc) if math.isfinite(m_unc) else cfg.m, cap
        )
        m_star = max(1, int(m_star))
        if refine_plateau:
            n_i = workload.n(i)
            cands = {m_star}
            x = math.ceil(n_i / m_star)
            cands.add(min(cap, math.ceil(n_i / x)))          # this plateau's edge
            if x > 1:
                cands.add(min(cap, math.ceil(n_i / (x - 1))))  # next plateau edge
            m_star = min(
                cands,
                key=lambda m: _period_pair_time(workload, cfg, i, m),
            )
        out.append(m_star)
    return out


def optimal_epoch_time(
    workload: FCNNWorkload, cfg: ONoCConfig, refine_plateau: bool = False
) -> tuple[float, list[int], list[PeriodCosts]]:
    """Theorem 1: T* with the Lemma-1 allocation."""
    stars = optimal_cores(workload, cfg, refine_plateau=refine_plateau)
    t, periods = epoch_time(workload, cfg, stars)
    return t, stars, periods


def brute_force_optimal_cores(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    candidates: Sequence[int] | None = None,
) -> list[int]:
    """Simulated optimum: per-period argmin over explicit core counts.

    T is separable per FP period (each m_i only affects periods i and
    2l-i+1 — Eq. 11), so the global argmin is the per-period argmin.  This
    mirrors the paper's per-layer sweep in Fig. 7.
    """
    l = workload.l
    if candidates is None:
        candidates = range(1, cfg.m + 1)
    out: list[int] = []
    for i in range(1, l + 1):
        best_m, best_t = 1, float("inf")
        cap = min(int(cfg.phi * cfg.m), workload.n(i))
        for m_i in candidates:
            if not 1 <= m_i <= cap:
                continue
            t = (
                compute_time(workload, cfg, i, m_i)
                + comm_time(workload, cfg, i, m_i)
                + compute_time(workload, cfg, 2 * l - i + 1, m_i)
                + comm_time(workload, cfg, 2 * l - i + 1, m_i)
            )
            if t < best_t - 1e-15:
                best_t, best_m = t, m_i
        out.append(best_m)
    return out


def _period_pair_time(
    workload: FCNNWorkload, cfg: ONoCConfig, i: int, m_i: int
) -> float:
    """Combined FP+BP time of the (i, 2l-i+1) period pair at m_i cores."""
    l = workload.l
    return (
        compute_time(workload, cfg, i, m_i)
        + comm_time(workload, cfg, i, m_i)
        + compute_time(workload, cfg, 2 * l - i + 1, m_i)
        + comm_time(workload, cfg, 2 * l - i + 1, m_i)
    )


def prediction_error(
    workload: FCNNWorkload,
    cfg: ONoCConfig,
    plateau_tol: float = 0.005,
    refine_plateau: bool = False,
) -> tuple[float, float, float]:
    """(APE_raw, APE_plateau, APD) as in paper Table 7.

    APE_raw:     mean |m* - argmin| / argmin over FP periods.  Unstable when
                 the objective is flat near the optimum (plateau degeneracy:
                 ceil(n_i/m) steps make many m time-equivalent).
    APE_plateau: mean distance from m* to the *set* of near-optimal core
                 counts (period-pair time within ``plateau_tol`` of the
                 minimum) — the argmin-stable analogue of the paper's APE.
    APD:         relative epoch-time difference of the m* plan vs argmin
                 plan (the paper's Average Performance Difference).
    """
    stars = optimal_cores(workload, cfg, refine_plateau=refine_plateau)
    sim = brute_force_optimal_cores(workload, cfg)
    ape_raw = float(np.mean([abs(a - b) / b for a, b in zip(stars, sim)]))

    l = workload.l
    plateau_err = []
    for i in range(1, l + 1):
        cap = min(int(cfg.phi * cfg.m), workload.n(i))
        times = np.array(
            [_period_pair_time(workload, cfg, i, m) for m in range(1, cap + 1)]
        )
        t_min = times.min()
        near = np.flatnonzero(times <= t_min * (1.0 + plateau_tol)) + 1
        m_star = stars[i - 1]
        d = np.min(np.abs(near - m_star) / near)
        plateau_err.append(float(d))
    ape_plateau = float(np.mean(plateau_err))

    t_star, _ = epoch_time(workload, cfg, stars)
    t_sim, _ = epoch_time(workload, cfg, sim)
    apd = abs(t_star - t_sim) / max(t_sim, 1e-30)
    return ape_raw, ape_plateau, float(apd)
