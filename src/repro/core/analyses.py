"""Mapping-strategy analyses — paper Sections 4.2–4.5.

  Theorem 2  max consecutive active periods (hotspot level)
  Table 1    state-transition counts per epoch
  Table 2    maximum routing-path length (crosstalk / insertion loss proxy)
  Eq. (19)   insertion loss of a routing path
  Eq. (20) / Table 3   per-core SRAM requirement
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import Mapping, MappingStrategy, neuron_assignment
from .onoc_model import FCNNWorkload

__all__ = [
    "hotspot_consecutive_periods",
    "state_transitions",
    "max_path_length",
    "insertion_loss_db",
    "OpticalLossParams",
    "memory_per_core_bytes",
    "max_memory_requirement_bytes",
    "StrategyReport",
    "analyze_mapping",
]


def hotspot_consecutive_periods(mapping: Mapping) -> int:
    """Maximum number of consecutive periods any core is active in one epoch
    (FP periods 1..l then BP periods l+1..2l = FP windows reversed) —
    the paper's hotspot metric (Theorem 2)."""
    l = mapping.l
    seq = [set(mapping.window(p)) for p in range(1, 2 * l + 1)]
    best = 0
    cores = set().union(*seq) if seq else set()
    for c in cores:
        run = 0
        for s in seq:
            run = run + 1 if c in s else 0
            best = max(best, run)
    return best


def state_transitions(mapping: Mapping) -> int:
    """Number of active<->idle transitions over one epoch, counted per core
    (2 per activation burst: one wake, one sleep) — Table 1's quantity,
    computed exactly from the placement rather than the closed forms."""
    l = mapping.l
    seq = [set(mapping.window(p)) for p in range(1, 2 * l + 1)]
    cores = set().union(*seq) if seq else set()
    transitions = 0
    for c in cores:
        active = [c in s for s in seq]
        bursts = 0
        prev = False
        for a in active:
            if a and not prev:
                bursts += 1
            prev = a
        transitions += 2 * bursts
    return transitions


def state_transitions_closed_form(mapping: Mapping) -> int:
    """Table 1's closed forms (for cross-checking against the exact count)."""
    ms = mapping.cores_per_period
    l = len(ms)
    if mapping.strategy is MappingStrategy.FM:
        return 2 * (ms[0] + sum(abs(ms[i] - ms[i - 1]) for i in range(1, l)))
    # For RRM/ORRM the paper's expressions cover the FP+BP epoch:
    #   RRM : 2(sum_{i=1..2l} m_i* - m_l*)          [period l and l+1 share cores]
    #   ORRM: 2(sum_{i=1..2l} m_i* - m_l* - sum r_i)
    total_2l = 2 * sum(ms)  # BP mirrors FP (Eq. 11)
    if mapping.strategy is MappingStrategy.RRM:
        return 2 * (total_2l - ms[-1])
    # ORRM: reuse happens between FP-adjacent, BP-adjacent and the FP->BP turn
    r = mapping.reuse
    return 2 * (total_2l - ms[-1] - 2 * sum(r))


def max_path_length(mapping: Mapping) -> int:
    """Table 2: the maximum routing-path length (in ring hops) over all
    period transitions.  A broadcast from period i's window to period i+1's
    window travels from the first sender to the farthest receiver."""
    l = mapping.l
    best = 0
    for i in range(1, 2 * l):  # transitions between consecutive periods
        senders = mapping.window(i)
        receivers = mapping.window(i + 1)
        if not senders or not receivers:
            continue
        # Path runs along the ring from each sender to the farthest receiver
        # in the transmission direction (clockwise in FP, counter-clockwise
        # in BP — symmetric on a ring, so use clockwise distance).
        for s in senders:
            far = max((r - s) % mapping.m for r in receivers)
            best = max(best, far)
    return best


@dataclasses.dataclass(frozen=True)
class OpticalLossParams:
    """Table 5's loss constants (dB).

    A transiting wavelength only suffers the MR *pass* loss (0.005 dB) at
    intermediate routers; the 0.5 dB MR *drop* loss and the 0.5 dB splitter
    apply once, at the receiver, and are folded into il_oe_db.  Link length
    is ~0.2 mm/hop for a 1000-router ring on a 20 mm die edge.
    """

    il_link_db: float = 1.5 * 0.02  # waveguide 1.5 dB/cm × 0.02 cm/hop
    il_router_db: float = 0.005     # MR pass loss per transited router
    il_eo_db: float = 1.0           # coupler (E->O injection)
    il_oe_db: float = 1.0           # splitter 0.5 + MR drop 0.5 at receiver


def insertion_loss_db(n_routers: int, p: OpticalLossParams | None = None) -> float:
    """Eq. (19): IL = IL_l (N_r - 1) + IL_r N_r + IL_eo + IL_oe."""
    p = p or OpticalLossParams()
    if n_routers < 1:
        return 0.0
    return (
        p.il_link_db * (n_routers - 1)
        + p.il_router_db * n_routers
        + p.il_eo_db
        + p.il_oe_db
    )


def memory_per_core_bytes(
    workload: FCNNWorkload,
    mapping: Mapping,
    psi_bytes: int = 4,
) -> np.ndarray:
    """Eq. (20): per-core SRAM requirement, exact from the mapping matrix.

    Per neuron of layer i the paper charges s_i = (3 n_{i-1} + 4) µ ψ
    (FP: n_{i-1} weights + 1 bias + n_{i-1} inputs + 1 output;
     BP adds n_{i-1} weight gradients + 1 bias gradient + 1 learning rate),
    with µ the batch size (inputs/outputs are per-sample; weights are not,
    the paper's s_i upper-bounds both by µψ).
    """
    mu = workload.batch_size
    mem = np.zeros(mapping.m, dtype=np.float64)
    assignment = neuron_assignment(workload, mapping)
    for layer, cores in assignment.items():
        n_prev = workload.n(layer - 1)
        s_i = (3 * n_prev + 4) * mu * psi_bytes
        np.add.at(mem, cores, s_i)
    return mem


def max_memory_requirement_bytes(
    workload: FCNNWorkload, mapping: Mapping, psi_bytes: int = 4
) -> float:
    """Table 3's quantity: max over cores of Eq. (20)."""
    return float(memory_per_core_bytes(workload, mapping, psi_bytes).max())


@dataclasses.dataclass(frozen=True)
class StrategyReport:
    strategy: str
    hotspot_consecutive_periods: int
    state_transitions: int
    state_transitions_closed_form: int
    max_path_length_hops: int
    worst_insertion_loss_db: float
    max_memory_bytes: float
    active_core_count: int


def analyze_mapping(
    workload: FCNNWorkload,
    mapping: Mapping,
    psi_bytes: int = 4,
    loss: OpticalLossParams | None = None,
) -> StrategyReport:
    """One-stop report used by benchmarks and the planner."""
    path = max_path_length(mapping)
    return StrategyReport(
        strategy=mapping.strategy.value,
        hotspot_consecutive_periods=hotspot_consecutive_periods(mapping),
        state_transitions=state_transitions(mapping),
        state_transitions_closed_form=state_transitions_closed_form(mapping),
        max_path_length_hops=path,
        worst_insertion_loss_db=insertion_loss_db(max(1, path + 1), loss),
        max_memory_bytes=max_memory_requirement_bytes(workload, mapping, psi_bytes),
        active_core_count=len(mapping.active_cores()),
    )
