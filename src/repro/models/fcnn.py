"""The paper's FCNN (MLP) — NN1..NN6 — with per-period ONoC-planned
parallelism as a first-class feature.

Layer i computes Y = A(W^T X + b) (Eq. 1): sigmoid in hidden layers,
softmax + cross-entropy (via log-softmax) at the output (paper §5.1).

The ONoC mapping enters through ``period_specs``: per layer, the output-
neuron axis is sharded at the planner-chosen degree — this is the paper's
"n_i neurons evenly mapped to m_i cores".  The forward all-gather of layer
outputs into the next period's cores is the WDM broadcast; JAX AD
transposes it into the BP reduce-scatter automatically, realizing the
paper's "senders in Period i become receivers in Period 2l-i+1"
(Example II) without any hand-written backward pass.

Heterogeneous layer shapes mean this model is NOT scanned — exactly like
the paper, each period is its own program phase.

Every period dispatches through ``kernels.ops.fcnn_layer``: on TPU that is
the fused Pallas forward (bias+activation in the GEMM epilogue) with a
``jax.custom_vjp`` backward running the fused dgrad/wgrad kernels, so both
passes of the hot loop avoid an HBM round-trip of the (B, n_i) activation
tensor; everywhere else it is the bit-compatible jnp oracle, differentiable
by ordinary autodiff.  The loss itself is the fused
``kernels.ops.softmax_xent`` output period (online-softmax forward, fused
dlogits backward), so every one of the 2l periods now runs fused on TPU.
``kernel_mode`` forces a dispatch mode (``"ref"`` / ``"pallas"`` /
``"pallas_interpret"``) for tests and benchmarks, and threads through
``loss_fn`` and ``accuracy`` alike so eval never takes a different path
than training.
"""

from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.parallel.sharding import shard_constraint

Params = dict[str, Any]


def init(key, layer_sizes: Sequence[int], dtype=jnp.float32) -> Params:
    """layer_sizes = [n_0, ..., n_l]."""
    layers = []
    keys = jax.random.split(key, len(layer_sizes) - 1)
    for i, k in enumerate(keys):
        n_in, n_out = layer_sizes[i], layer_sizes[i + 1]
        w = jax.random.normal(k, (n_in, n_out), jnp.float32) / math.sqrt(n_in)
        layers.append({
            "w": w.astype(dtype),
            "b": jnp.zeros((n_out,), dtype=dtype),
        })
    return {"layers": layers}


def param_axes(layer_sizes: Sequence[int],
               degrees: Sequence[int] | None = None) -> Params:
    """Logical axes per layer.  A layer planned at degree 1 is replicated;
    otherwise its output-neuron axis carries the "mlp" logical axis (the
    planner maps it to the mesh axes that realize the degree)."""
    l = len(layer_sizes) - 1
    degrees = list(degrees) if degrees is not None else [0] * l
    layers = []
    for i in range(l):
        if degrees[i] == 1:
            layers.append({"w": (None, None), "b": (None,)})
        else:
            layers.append({"w": ("embed", "mlp"), "b": ("mlp",)})
    return {"layers": layers}


def period_activation(layer: int, l: int) -> str:  # noqa: E741 — paper notation
    """Activation of FP period/layer ``layer`` (1-based) in an l-layer FCNN:
    sigmoid in hidden layers, none at the output (softmax lives in the loss
    period).  Single source of truth shared by ``forward`` and the period-
    program compiler (exec/program.py), so a compiled schedule can never
    disagree with the reference forward pass."""
    return "sigmoid" if layer < l else "none"


def forward(params: Params, x: jax.Array,
            kernel_mode: str | None = None) -> jax.Array:
    """x: (B, n_0) -> logits (B, n_l).  Period i = one loop iteration."""
    h = x
    n = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        act = period_activation(i + 1, n)
        h = ops.fcnn_layer(h, lp["w"], lp["b"], act, force=kernel_mode)
        if i < n - 1:
            # the paper's inter-period broadcast: outputs leave this
            # period's cores for the next period's cores
            h = shard_constraint(h, ("activation_batch", "activation_mlp"))
    return h


def loss_fn(params: Params, batch: Params,
            kernel_mode: str | None = None) -> jax.Array:
    """Mean softmax cross-entropy — the fused output period.

    Dispatches through ``kernels.ops.softmax_xent`` under the same mode as
    the layer kernels: on TPU the online-softmax Pallas forward + fused
    dlogits backward (probabilities/log-probs never reach HBM), elsewhere
    the jnp oracle (identical to the pre-fusion log-softmax + NLL loss).
    """
    logits = forward(params, batch["x"], kernel_mode=kernel_mode)
    return ops.softmax_xent(logits, batch["y"], force=kernel_mode)


def accuracy(params: Params, x: jax.Array, y: jax.Array,
             kernel_mode: str | None = None) -> jax.Array:
    """Eval takes the same dispatch path as training (``kernel_mode``
    threads through exactly like ``loss_fn``)."""
    logits = forward(params, x, kernel_mode=kernel_mode)
    return jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
