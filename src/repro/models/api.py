"""Uniform model interface: ``get_model(cfg)`` returns a ``Model`` whose
functions close over nothing — params/batches are explicit pytrees, so
every function jits and shards cleanly.

Families: dense | moe | ssm | hybrid | encdec | vlm.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable[..., Params]
    param_axes: Callable[[], Params]
    forward: Callable[..., jax.Array]
    loss_fn: Callable[..., jax.Array]
    init_cache: Callable[..., Params]
    cache_axes: Callable[[], Params]
    prefill: Callable[..., tuple]
    decode_step: Callable[..., tuple]
    input_specs: Callable[[ShapeSpec], Params]
    batch_axes: Callable[[ShapeSpec], Params]


def _lm_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Params:
    b = shape.global_batch
    if shape.kind == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
    if shape.kind == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)}
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _lm_batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> Params:
    ax = ("activation_batch", None)
    if shape.kind == "train":
        return {"tokens": ax, "labels": ax}
    return {"tokens": ax}


def _vlm_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Params:
    b = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        return {
            "embeds": jax.ShapeDtypeStruct((b, shape.seq_len, cfg.d_model), dt),
            "positions": jax.ShapeDtypeStruct((3, b, shape.seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32),
        }
    if shape.kind == "prefill":
        return {
            "embeds": jax.ShapeDtypeStruct((b, shape.seq_len, cfg.d_model), dt),
            "positions": jax.ShapeDtypeStruct((3, b, shape.seq_len), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _vlm_batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> Params:
    if shape.kind == "decode":
        return {"tokens": ("activation_batch", None)}
    out = {
        "embeds": ("activation_batch", "activation_length", "activation_embed"),
        "positions": (None, "activation_batch", None),
    }
    if shape.kind == "train":
        out["labels"] = ("activation_batch", None)
    return out


def _encdec_input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Params:
    b = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    enc_len = shape.seq_len // 2
    dec_len = shape.seq_len - enc_len
    if shape.kind == "train":
        return {
            "enc_embeds": jax.ShapeDtypeStruct((b, enc_len, cfg.d_model), dt),
            "dec_tokens": jax.ShapeDtypeStruct((b, dec_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, dec_len), jnp.int32),
        }
    if shape.kind == "prefill":
        return {
            "enc_embeds": jax.ShapeDtypeStruct((b, enc_len, cfg.d_model), dt),
            "dec_tokens": jax.ShapeDtypeStruct((b, dec_len), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}


def _encdec_batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> Params:
    if shape.kind == "decode":
        return {"tokens": ("activation_batch", None)}
    out = {
        "enc_embeds": ("activation_batch", "activation_length",
                       "activation_embed"),
        "dec_tokens": ("activation_batch", None),
    }
    if shape.kind == "train":
        out["labels"] = ("activation_batch", None)
    return out


def get_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense",):
        from repro.models import transformer as mod
        specs, baxes = _lm_input_specs, _lm_batch_axes
    elif fam == "moe":
        from repro.models import moe as mod
        specs, baxes = _lm_input_specs, _lm_batch_axes
    elif fam == "ssm":
        from repro.models import mamba2 as mod
        specs, baxes = _lm_input_specs, _lm_batch_axes
    elif fam == "hybrid":
        from repro.models import zamba2 as mod
        specs, baxes = _lm_input_specs, _lm_batch_axes
    elif fam == "encdec":
        from repro.models import encdec as mod
        specs, baxes = _encdec_input_specs, _encdec_batch_axes
    elif fam == "vlm":
        from repro.models import vlm as mod
        specs, baxes = _vlm_input_specs, _vlm_batch_axes
    else:
        raise ValueError(f"unknown family {fam!r}")

    def init_cache(batch: int, max_len: int, **kw):
        if fam == "encdec":
            return mod.init_cache(cfg, batch, max_len,
                                  kw.get("enc_len", max_len))
        return mod.init_cache(cfg, batch, max_len)

    return Model(
        cfg=cfg,
        init=lambda key: mod.init(key, cfg),
        param_axes=lambda: mod.param_axes(cfg),
        forward=lambda p, b: mod.forward(p, b, cfg),
        loss_fn=lambda p, b: mod.loss_fn(p, b, cfg),
        init_cache=init_cache,
        cache_axes=lambda: mod.cache_axes(cfg),
        prefill=lambda p, b, max_len: mod.prefill(p, b, cfg, max_len),
        decode_step=lambda p, c, b: mod.decode_step(p, c, b, cfg),
        input_specs=lambda shape: specs(cfg, shape),
        batch_axes=lambda shape: baxes(cfg, shape),
    )
