"""Encoder–decoder backbone (seamless-m4t-large-v2 style).

The audio/text modality frontend is a STUB per the assignment: the batch
carries precomputed frame embeddings ``enc_embeds`` (B, S_enc, d_model)
(what the conformer feature extractor would produce) — see
configs/seamless_m4t_large_v2.input_specs.

Structure: ``n_encoder_layers`` bidirectional encoder blocks, then
``n_layers`` decoder blocks each with self-attention (causal) +
cross-attention over the encoder memory + MLP.  Both stacks are scanned.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.sharding import shard_constraint

Params = dict[str, Any]


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def init_enc_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_dec_block(key, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "self_attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.resolved_head_dim,
                                      dtype),
        "ln_x": L.init_rms_norm(cfg.d_model, dtype),
        "cross_attn": L.init_attention(k2, cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.resolved_head_dim,
                                       dtype),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def enc_block_axes(cfg: ModelConfig) -> Params:
    return {
        "ln1": {"scale": (None,)},
        "attn": L.attention_param_axes(),
        "ln2": {"scale": (None,)},
        "mlp": dict(L.MLP_AXES),
    }


def dec_block_axes(cfg: ModelConfig) -> Params:
    return {
        "ln1": {"scale": (None,)},
        "self_attn": L.attention_param_axes(),
        "ln_x": {"scale": (None,)},
        "cross_attn": L.attention_param_axes(),
        "ln2": {"scale": (None,)},
        "mlp": dict(L.MLP_AXES),
    }


def enc_block_apply(p, h, positions, cfg: ModelConfig):
    a = L.attention(p["attn"], L.rms_norm(p["ln1"], h, cfg.norm_eps),
                    positions, theta=cfg.rope_theta, eps=cfg.norm_eps,
                    causal=False, unroll=L.scan_unroll_of(cfg))
    h = h + a
    return h + L.mlp(p["mlp"], L.rms_norm(p["ln2"], h, cfg.norm_eps))


def dec_block_apply(p, h, memory_kv, positions, cfg: ModelConfig):
    a = L.attention(p["self_attn"], L.rms_norm(p["ln1"], h, cfg.norm_eps),
                    positions, theta=cfg.rope_theta, eps=cfg.norm_eps,
                    causal=True, unroll=L.scan_unroll_of(cfg),
                    chunk_threshold=cfg.attn_chunk_threshold)
    h = h + a
    x = L.attention(p["cross_attn"], L.rms_norm(p["ln_x"], h, cfg.norm_eps),
                    positions, theta=cfg.rope_theta, eps=cfg.norm_eps,
                    causal=False, kv_override=memory_kv)
    h = h + x
    return h + L.mlp(p["mlp"], L.rms_norm(p["ln2"], h, cfg.norm_eps))


# --------------------------------------------------------------------------
# assembly
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    k_e, k_enc, k_dec, k_u = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    enc_keys = jax.random.split(k_enc, cfg.n_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_layers)
    return {
        "embedding": L.init_embedding(k_e, cfg.padded_vocab, cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: init_enc_block(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: init_dec_block(k, cfg))(dec_keys),
        "enc_norm": L.init_rms_norm(cfg.d_model, dtype),
        "final_norm": L.init_rms_norm(cfg.d_model, dtype),
        "unembed": L.init_embedding(k_u, cfg.padded_vocab, cfg.d_model, dtype),
    }


def param_axes(cfg: ModelConfig) -> Params:
    def stack(t):
        return jax.tree.map(lambda ax: ("layers",) + tuple(ax), t,
                            is_leaf=lambda x: isinstance(x, tuple))
    return {
        "embedding": {"w": ("vocab", "table_embed")},
        "encoder": stack(enc_block_axes(cfg)),
        "decoder": stack(dec_block_axes(cfg)),
        "enc_norm": {"scale": (None,)},
        "final_norm": {"scale": (None,)},
        "unembed": {"w": ("vocab", "table_embed")},
    }


def encode(params, enc_embeds, cfg: ModelConfig):
    h = enc_embeds.astype(jnp.dtype(cfg.dtype))
    h = shard_constraint(h, ("activation_batch", "activation_length",
                             "activation_embed"))
    b, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, lp):
        return enc_block_apply(lp, carry, positions, cfg), None

    body = L.remat_wrap(cfg, body)
    h, _ = lax.scan(body, h, params["encoder"],
                    unroll=L.scan_unroll_of(cfg))
    return L.rms_norm(params["enc_norm"], h, cfg.norm_eps)


def _memory_kv(params, memory, positions_mem, cfg):
    """Per-decoder-layer (K, V) of the encoder memory, stacked (Ld, ...)."""
    def kv_one(lp):
        return L.prefill_attention_kv(lp["cross_attn"], memory, positions_mem,
                                      theta=cfg.rope_theta, eps=cfg.norm_eps)
    return jax.vmap(kv_one)(params["decoder"])


def decode_stack(params, h, memory, positions, cfg: ModelConfig):
    b, sm = memory.shape[0], memory.shape[1]
    pos_mem = jnp.broadcast_to(jnp.arange(sm, dtype=jnp.int32), (b, sm))
    mem_k, mem_v = _memory_kv(params, memory, pos_mem, cfg)

    def body(carry, xs):
        lp, mk, mv = xs
        return dec_block_apply(lp, carry, (mk, mv), positions, cfg), None

    body = L.remat_wrap(cfg, body)
    h, _ = lax.scan(body, h, (params["decoder"], mem_k, mem_v),
                    unroll=L.scan_unroll_of(cfg))
    return h


def forward(params, batch, cfg: ModelConfig):
    memory = encode(params, batch["enc_embeds"], cfg)
    h = L.embed(params["embedding"], batch["dec_tokens"],
                onehot=cfg.embed_onehot)
    b, s = batch["dec_tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = decode_stack(params, h, memory, positions, cfg)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    return L.unembed(params["unembed"], h)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


# --------------------------------------------------------------------------
# serving: prefill encodes + seeds decoder self-attn cache; cross-attn KV
# is computed once at prefill and carried in the cache.
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    kv, d = cfg.n_kv_heads, cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, kv, d), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, kv, d), dtype),
        "mem_k": jnp.zeros((cfg.n_layers, batch, enc_len, kv, d), dtype),
        "mem_v": jnp.zeros((cfg.n_layers, batch, enc_len, kv, d), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> Params:
    ax = ("layers", "cache_batch", "cache_length", "cache_kv_heads",
          "cache_head_dim")
    return {"k": ax, "v": ax, "mem_k": ax, "mem_v": ax, "len": ("cache_batch",)}


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    memory = encode(params, batch["enc_embeds"], cfg)
    b, sm = memory.shape[0], memory.shape[1]
    pos_mem = jnp.broadcast_to(jnp.arange(sm, dtype=jnp.int32), (b, sm))
    mem_k, mem_v = _memory_kv(params, memory, pos_mem, cfg)

    dec = batch["dec_tokens"]
    s = dec.shape[1]
    h = L.embed(params["embedding"], dec)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, xs):
        lp, mk, mv = xs
        hh = carry
        k, v = L.prefill_attention_kv(lp["self_attn"],
                                      L.rms_norm(lp["ln1"], hh, cfg.norm_eps),
                                      positions, theta=cfg.rope_theta,
                                      eps=cfg.norm_eps)
        hh = dec_block_apply(lp, hh, (mk, mv), positions, cfg)
        return hh, (k, v)

    body = L.remat_wrap(cfg, body)
    h, (k_all, v_all) = lax.scan(body, h, (params["decoder"], mem_k, mem_v),
                                 unroll=L.scan_unroll_of(cfg))

    pad = max_len - s
    k_all = jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_all = jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["unembed"], h[:, -1:, :])
    cache = {"k": k_all, "v": v_all, "mem_k": mem_k, "mem_v": mem_v,
             "len": jnp.full((b,), s, jnp.int32)}
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig):
    h = L.embed(params["embedding"], batch["tokens"])
    cache_len = cache["len"]
    pos = cache_len[:, None].astype(jnp.int32)

    def body(carry, xs):
        lp, ck, cv, mk, mv = xs
        hh = carry
        a, ck, cv = L.decode_attention(
            lp["self_attn"], L.rms_norm(lp["ln1"], hh, cfg.norm_eps),
            ck, cv, cache_len, pos, theta=cfg.rope_theta, eps=cfg.norm_eps)
        hh = hh + a
        x = L.attention(lp["cross_attn"],
                        L.rms_norm(lp["ln_x"], hh, cfg.norm_eps),
                        pos, theta=cfg.rope_theta, eps=cfg.norm_eps,
                        causal=False, kv_override=(mk, mv))
        hh = hh + x
        hh = hh + L.mlp(lp["mlp"], L.rms_norm(lp["ln2"], hh, cfg.norm_eps))
        return hh, (ck, cv)

    h, (nk, nv) = lax.scan(
        body, h,
        (params["decoder"], cache["k"], cache["v"],
         cache["mem_k"], cache["mem_v"]),
        unroll=L.scan_unroll_of(cfg))
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    logits = L.unembed(params["unembed"], h)
    new_cache = dict(cache, k=nk, v=nv, len=cache_len + 1)
    return logits, new_cache
