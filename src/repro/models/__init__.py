"""Architecture zoo (pure functional JAX)."""
