"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

The block computes, per head h with state size N and head dim P:

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T        (state update)
    y_t = C_t h_t + D x_t                              (readout)

trained with the chunked "SSD" algorithm: intra-chunk quadratic attention-
like term + inter-chunk recurrence on chunk states, both expressed as
einsums (this file is also the oracle for kernels/ssd_scan.py).

Sequence-parallel note for the ONoC planner: the inter-chunk recurrence is
a carry chain (collective-permute on TPU), not a broadcast — outside the
paper's comm model; see DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.sharding import shard_constraint

Params = dict[str, Any]


# --------------------------------------------------------------------------
# SSD core (chunked scan) — pure jnp reference
# --------------------------------------------------------------------------

def segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) with out[..., i, j] = sum_{k=j+1..i} x_k
    for j <= i, -inf above the diagonal."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(x, dt_a, b, c, chunk: int, initial_state=None,
                unroll: bool | int = 1):
    """Chunked SSD.

    x:    (B, L, H, P)   head inputs (already multiplied by nothing; dt is
                          folded into B via dt*B per the SSD convention here)
    dt_a: (B, L, H)      log-decay per step (= dt * A, negative)
    b, c: (B, L, G, N)   input/output projections (G groups broadcast over H)
    Returns (y, final_state) with y (B, L, H, P), state (B, H, P, N).
    """
    bs, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    nc = l // chunk
    rep = h // g

    xc = x.reshape(bs, nc, chunk, h, p)
    ac = dt_a.reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2)   # (B,H,C,Q)
    bc = b.reshape(bs, nc, chunk, g, n)
    cc = c.reshape(bs, nc, chunk, g, n)
    # broadcast groups over heads
    bh = jnp.repeat(bc, rep, axis=3)                            # (B,C,Q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    a_cum = jnp.cumsum(ac, axis=-1)                             # (B,H,C,Q)

    # 1. intra-chunk (diagonal blocks)
    lmat = jnp.exp(segsum(ac))                                  # (B,H,C,Q,Q)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        ch, bh, lmat.astype(ch.dtype), xc,
                        preferred_element_type=jnp.float32)

    # 2. per-chunk final states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)             # (B,H,C,Q)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn",
                        bh, decay_states.astype(bh.dtype), xc,
                        preferred_element_type=jnp.float32)     # (B,C,H,P,N)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(a_cum[..., -1])                       # (B,H,C)

    if initial_state is None:
        initial_state = jnp.zeros((bs, h, p, n), dtype=states.dtype)

    def chunk_body(carry, xs):
        s_c, d_c = xs                                           # (B,H,P,N),(B,H)
        new = carry * d_c[..., None, None] + s_c
        return new, carry                                       # emit state *entering* chunk

    states_t = jnp.moveaxis(states, 1, 0)                       # (C,B,H,P,N)
    decay_t = jnp.moveaxis(chunk_decay, 2, 0)                   # (C,B,H)
    final_state, entry_states = lax.scan(
        chunk_body, initial_state.astype(states.dtype), (states_t, decay_t),
        unroll=unroll)
    entry_states = jnp.moveaxis(entry_states, 0, 1)             # (B,C,H,P,N)

    # 4. state -> output within each chunk
    state_decay = jnp.exp(a_cum)                                # (B,H,C,Q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       ch, entry_states.astype(ch.dtype),
                       state_decay.astype(ch.dtype),
                       preferred_element_type=jnp.float32)

    y = (y_diag + y_off).reshape(bs, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x, dt_a, b, c):
    """One-token recurrence.  state: (B,H,P,N); x: (B,H,P); dt_a: (B,H);
    b, c: (B,G,N).  Returns (y (B,H,P), new_state)."""
    h = x.shape[1]
    g = b.shape[1]
    rep = h // g
    bh = jnp.repeat(b, rep, axis=1)                             # (B,H,N)
    ch = jnp.repeat(c, rep, axis=1)
    decay = jnp.exp(dt_a)[..., None, None]                      # (B,H,1,1)
    upd = jnp.einsum("bhn,bhp->bhpn", bh, x,
                     preferred_element_type=jnp.float32)
    new_state = state * decay + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch,
                   preferred_element_type=jnp.float32)
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    h = cfg.ssm_heads
    conv_dim = d_in + 2 * g * n
    return d_in, g, n, h, conv_dim


def init_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    d_in, g, n, h, conv_dim = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_in + 2 * g * n + h
    s = 1.0 / math.sqrt(d)
    # dt bias init: softplus^-1 of dt in [1e-3, 1e-1] (mamba convention)
    u = jax.random.uniform(ks[2], (h,), minval=math.log(1e-3),
                           maxval=math.log(1e-1))
    dt_init = jnp.exp(u)
    dt_bias = dt_init + jnp.log(-jnp.expm1(-dt_init))
    return {
        "norm": L.init_rms_norm(d, dtype),
        "in_proj": {"w": (jax.random.normal(ks[0], (d, proj_out)) * s).astype(dtype)},
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, conv_dim))
                   * (1.0 / math.sqrt(cfg.conv_kernel))).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gated_norm": L.init_rms_norm(d_in, dtype),
        "out_proj": {"w": (jax.random.normal(ks[3], (d_in, d))
                           * (1.0 / math.sqrt(d_in))).astype(dtype)},
    }


def block_axes(cfg: ModelConfig) -> Params:
    return {
        "norm": {"scale": (None,)},
        "in_proj": {"w": ("embed", "mlp")},       # fused proj sharded on TP
        "conv_w": ("conv_kernel", "activation_mlp"),
        "conv_b": ("activation_mlp",),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "gated_norm": {"scale": ("activation_mlp",)},
        "out_proj": {"w": ("mlp", "embed")},
    }


def _split_proj(z_xbc_dt, cfg: ModelConfig):
    d_in, g, n, h, conv_dim = _dims(cfg)
    z = z_xbc_dt[..., :d_in]
    xbc = z_xbc_dt[..., d_in : d_in + conv_dim]
    dt = z_xbc_dt[..., d_in + conv_dim :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, prev_tail=None):
    """Depthwise causal conv along L.  xbc: (B, L, C); conv_w: (K, C)."""
    k = conv_w.shape[0]
    if prev_tail is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = prev_tail
    xp = jnp.concatenate([pad, xbc], axis=1)                    # (B, L+K-1, C)
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(k):                                          # K is tiny (4)
        out = out + xp[:, i : i + xbc.shape[1], :].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    out = out + conv_b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype), xp[:, -(k - 1):, :]


def block_apply(p: Params, hidden, positions, cfg: ModelConfig,
                initial_state=None, conv_tail=None, return_states=False):
    """Full-sequence mamba2 mixer with pre-norm and residual."""
    d_in, g, n, h, conv_dim = _dims(cfg)
    bsz, l, _ = hidden.shape
    x_in = L.rms_norm(p["norm"], hidden, cfg.norm_eps)
    zxbcdt = jnp.einsum("bld,dk->blk", x_in, p["in_proj"]["w"],
                        preferred_element_type=jnp.float32).astype(hidden.dtype)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xs = xbc[..., :d_in].reshape(bsz, l, h, d_in // h)
    b = xbc[..., d_in : d_in + g * n].reshape(bsz, l, g, n)
    c = xbc[..., d_in + g * n :].reshape(bsz, l, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)
    a = -jnp.exp(p["a_log"])                                     # (H,)
    dt_a = dt * a                                                # (B,L,H) <= 0
    # fold dt into the input branch (SSD convention: x <- x * dt)
    x_dt = (xs.astype(jnp.float32) * dt[..., None]).astype(xs.dtype)
    y, final_state = ssd_chunked(x_dt, dt_a, b, c, cfg.ssm_chunk,
                                 initial_state,
                                 unroll=L.scan_unroll_of(cfg))
    y = y + xs * p["d_skip"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, l, d_in)
    y = L.rms_norm(p["gated_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"]["w"],
                     preferred_element_type=jnp.float32).astype(hidden.dtype)
    out = shard_constraint(out, ("activation_batch", "residual_length",
                                 "activation_embed"))
    res = hidden + out
    if return_states:
        return res, (final_state, tail)
    return res


def block_decode(p: Params, hidden, ssm_state, conv_tail, cfg: ModelConfig):
    """One-token step.  hidden: (B,1,d)."""
    d_in, g, n, h, conv_dim = _dims(cfg)
    bsz = hidden.shape[0]
    x_in = L.rms_norm(p["norm"], hidden, cfg.norm_eps)
    zxbcdt = jnp.einsum("bld,dk->blk", x_in, p["in_proj"]["w"],
                        preferred_element_type=jnp.float32).astype(hidden.dtype)
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_tail)
    xs = xbc[:, 0, :d_in].reshape(bsz, h, d_in // h)
    b = xbc[:, 0, d_in : d_in + g * n].reshape(bsz, g, n)
    c = xbc[:, 0, d_in + g * n :].reshape(bsz, g, n)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    x_dt = (xs.astype(jnp.float32) * dt1[..., None]).astype(xs.dtype)
    y, new_state = ssd_decode_step(ssm_state, x_dt, dt1 * a, b, c)
    y = y + xs * p["d_skip"][None, :, None].astype(xs.dtype)
    y = y.reshape(bsz, 1, d_in)
    y = L.rms_norm(p["gated_norm"], y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                   cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"]["w"],
                     preferred_element_type=jnp.float32).astype(hidden.dtype)
    return hidden + out, new_state, tail


# --------------------------------------------------------------------------
# whole LM (attention-free)
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    k_e, k_l, k_u = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(k_l, cfg.n_layers)
    p: Params = {
        "embedding": L.init_embedding(k_e, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(keys),
        "final_norm": L.init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embedding(k_u, cfg.padded_vocab, cfg.d_model, dtype)
    return p


def param_axes(cfg: ModelConfig) -> Params:
    base = block_axes(cfg)
    stacked = jax.tree.map(lambda ax: ("layers",) + tuple(ax), base,
                           is_leaf=lambda x: isinstance(x, tuple))
    p: Params = {
        "embedding": {"w": ("vocab", "table_embed")},
        "layers": stacked,
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": ("vocab", "table_embed")}
    return p


def forward(params, batch, cfg: ModelConfig):
    h = L.embed(params["embedding"], batch["tokens"], onehot=cfg.embed_onehot)

    def body(carry, lp):
        return block_apply(lp, carry, None, cfg), None

    body = L.remat_wrap(cfg, body)
    h, _ = lax.scan(body, h, params["layers"], unroll=L.scan_unroll_of(cfg))
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(emb, h)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    """SSM 'cache' = recurrent state; constant size — the long_500k story."""
    d_in, g, n, h, conv_dim = _dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, d_in // h, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, conv_dim),
                          dtype=dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> Params:
    return {
        "ssm": ("layers", "cache_batch", "activation_heads", None, None),
        "conv": ("layers", "cache_batch", None, "activation_mlp"),
        "len": ("cache_batch",),
    }


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    h = L.embed(params["embedding"], batch["tokens"], onehot=cfg.embed_onehot)
    bsz, s = batch["tokens"].shape

    def body(carry, lp):
        hh = carry
        hh, (state, tail) = block_apply(lp, hh, None, cfg, return_states=True)
        return hh, (state, tail)

    body = L.remat_wrap(cfg, body)
    h, (states, tails) = lax.scan(body, h, params["layers"],
                                  unroll=L.scan_unroll_of(cfg))
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h[:, -1:, :])
    cache = {"ssm": states.astype(jnp.float32), "conv": tails,
             "len": jnp.full((bsz,), s, jnp.int32)}
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig):
    h = L.embed(params["embedding"], batch["tokens"])

    def body(carry, xs):
        lp, st, tail = xs
        hh, new_st, new_tail = block_decode(lp, carry, st, tail, cfg)
        return hh, (new_st, new_tail)

    h, (new_ssm, new_conv) = lax.scan(
        body, h, (params["layers"], cache["ssm"], cache["conv"]),
        unroll=L.scan_unroll_of(cfg))
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h)
    return logits, {"ssm": new_ssm, "conv": new_conv, "len": cache["len"] + 1}
