"""Token-choice top-k Mixture-of-Experts transformer (granite-3.0-moe,
qwen2-moe with shared experts).

Dispatch uses the grouped one-hot einsum formulation (Mesh-TF / MaxText
style): tokens are split into groups of ``moe_group_size``; within a group
each expert accepts at most C = ceil(group · k / E · capacity_factor)
tokens (overflow dropped, standard for capacity-based MoE).  The dispatch
einsum contracts a (G, T, E, C) one-hot against (G, T, d) activations and,
with tokens sharded on "data" and experts on "model" (EP), XLA lowers the
boundary into the canonical MoE all-to-all pair.

The router aux (load-balance) loss is threaded through the layer-scan carry
— no out-of-band state, no leaked tracers.

ONoC-planner note (DESIGN.md §Arch-applicability): experts map onto the
paper's "neurons evenly mapped to m_i cores" with the all-to-all replacing
the ring broadcast; g() gains an all-to-all term in core/planner.py.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import shard_constraint

Params = dict[str, Any]


def init_moe_mlp(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.param_dtype)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p: Params = {
        "router": (jax.random.normal(ks[0], (d, e)) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * s_out).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = L.init_mlp(
            ks[4], d, cfg.n_shared_experts * cfg.moe_d_ff, dtype)
    return p


def moe_mlp_axes(cfg: ModelConfig) -> Params:
    p: Params = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts > 0:
        p["shared"] = dict(L.MLP_AXES)
    return p


def moe_mlp(p: Params, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    tokens = x.reshape(b * s, d)
    n = tokens.shape[0]
    g_size = min(cfg.moe_group_size, n)
    n_groups = -(-n // g_size)                                  # ceil
    padded = n_groups * g_size
    valid = (jnp.arange(padded) < n).astype(jnp.float32)
    if padded > n:
        tokens = jnp.pad(tokens, ((0, padded - n), (0, 0)))
    tokens = tokens.reshape(n_groups, g_size, d)
    valid = valid.reshape(n_groups, g_size)                     # (G,T)

    logits = jnp.einsum("gtd,de->gte", tokens.astype(jnp.float32),
                        p["router"])                            # (G,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)             # (G,T,k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # (G,T,k,E)
    gates_full = jnp.einsum("gtk,gtke->gte", gate_vals, onehot)
    sel = jnp.sum(onehot, axis=2)                               # (G,T,E) 0/1
    # padding tokens route nowhere and consume no expert capacity
    sel = sel * valid[..., None]
    gates_full = gates_full * valid[..., None]

    cap = max(1, int(math.ceil(g_size * k / e * cfg.capacity_factor)))
    pos = (jnp.cumsum(sel, axis=1) - 1.0) * sel                 # queue slot
    keep = sel * (pos < cap)
    disp = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    disp = disp * keep[..., None]                               # (G,T,E,C)
    combine = gates_full[..., None] * disp

    # Switch-style load-balance aux loss
    me = jnp.mean(sel, axis=1)
    ce = jnp.mean(probs, axis=1)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e

    xin = jnp.einsum("gtec,gtd->gecd", disp.astype(x.dtype), tokens,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    xin = shard_constraint(xin, (None, "activation_exp", None, None))
    hg = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"],
                    preferred_element_type=jnp.float32)
    hu = jnp.einsum("gecd,edf->gecf", xin, p["w_up"],
                    preferred_element_type=jnp.float32)
    hh = (jax.nn.silu(hg) * hu).astype(x.dtype)
    out_e = jnp.einsum("gecf,efd->gecd", hh, p["w_down"],
                       preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), out_e,
                   preferred_element_type=jnp.float32).astype(x.dtype)

    y = y.reshape(padded, d)[:n]
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + L.mlp(p["shared"], x)
    y = shard_constraint(y, ("activation_batch", "residual_length",
                             "activation_embed"))
    return y, aux


# ------------------------- block + assembly -------------------------------

def init_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dtype,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "moe": init_moe_mlp(key=k2, cfg=cfg),
    }


def block_axes(cfg: ModelConfig) -> Params:
    return {
        "ln1": {"scale": (None,)},
        "attn": L.attention_param_axes(cfg.qkv_bias, cfg.qk_norm),
        "ln2": {"scale": (None,)},
        "moe": moe_mlp_axes(cfg),
    }


def block_apply_aux(p: Params, h, positions, cfg: ModelConfig):
    a = L.attention(p["attn"], L.rms_norm(p["ln1"], h, cfg.norm_eps),
                    positions, theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                    eps=cfg.norm_eps, causal=True,
                    unroll=L.scan_unroll_of(cfg),
                    chunk_threshold=cfg.attn_chunk_threshold)
    h = h + a
    y, aux = moe_mlp(p["moe"], L.rms_norm(p["ln2"], h, cfg.norm_eps), cfg)
    return h + y, aux


def block_apply(p: Params, h, positions, cfg: ModelConfig):
    return block_apply_aux(p, h, positions, cfg)[0]


def block_decode(p: Params, h, ck, cv, cache_len, positions, cfg: ModelConfig):
    a, ck, cv = L.decode_attention(
        p["attn"], L.rms_norm(p["ln1"], h, cfg.norm_eps), ck, cv, cache_len,
        positions, theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
        eps=cfg.norm_eps, window=cfg.attn_window)
    h = h + a
    y, _ = moe_mlp(p["moe"], L.rms_norm(p["ln2"], h, cfg.norm_eps), cfg)
    return h + y, ck, cv


def init(key, cfg: ModelConfig) -> Params:
    return T.init(key, cfg, init_one=init_block)

def param_axes(cfg: ModelConfig) -> Params:
    return T.param_axes(cfg, one_axes=block_axes)

def forward(params, batch, cfg: ModelConfig):
    return T.forward(params, batch, cfg, apply_one=block_apply)


def loss_fn(params, batch, cfg: ModelConfig):
    """Cross-entropy + router aux, aux threaded through the scan carry."""
    h = T._embed_in(params, batch, cfg)
    positions = T._positions_of(batch, cfg)

    def body(carry, lp):
        hh, aux = carry
        hh, a = block_apply_aux(lp, hh, positions, cfg)
        return (hh, aux + a), None

    body = L.remat_wrap(cfg, body)
    (h, aux), _ = lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                           params["layers"], unroll=L.scan_unroll_of(cfg))
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h)
    loss = L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    return loss + cfg.router_aux_coef * aux / cfg.n_layers


init_cache = T.init_cache
cache_axes = T.cache_axes

def prefill(params, batch, cfg: ModelConfig, max_len: int):
    return T.prefill(params, batch, cfg, max_len, apply_one=block_apply)

def decode_step(params, cache, batch, cfg: ModelConfig):
    return T.decode_step(params, cache, batch, cfg, decode_one=block_decode)
