"""Qwen2-VL-style VLM backbone: the dense transformer with M-RoPE.

The vision frontend (ViT patch encoder, dynamic resolution) is a STUB per
the assignment — ``input_specs`` provides precomputed patch/text embeddings
(B, S, d_model) and a 3-stream position tensor (3, B, S) for M-RoPE
(temporal / height / width).  Everything else delegates to transformer.py;
cfg.mrope_sections activates the sectioned rotary in layers.apply_mrope.
"""

from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as T

Params = dict[str, Any]

init = T.init
param_axes = T.param_axes
forward = T.forward
loss_fn = T.loss_fn
init_cache = T.init_cache
cache_axes = T.cache_axes
prefill = T.prefill
decode_step = T.decode_step


def make_text_positions(batch_size: int, seq_len: int) -> jnp.ndarray:
    """Text-only M-RoPE positions: all three streams equal (the Qwen2-VL
    convention for pure-text segments)."""
    pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32),
                           (batch_size, seq_len))
    return jnp.broadcast_to(pos, (3, batch_size, seq_len))


def make_image_positions(batch_size: int, t: int, h: int, w: int) -> jnp.ndarray:
    """Grid M-RoPE positions for a (t, h, w) patch grid, flattened to a
    sequence: temporal/height/width streams index their own grid axis."""
    tt = jnp.repeat(jnp.arange(t), h * w)
    hh = jnp.tile(jnp.repeat(jnp.arange(h), w), t)
    ww = jnp.tile(jnp.arange(w), t * h)
    pos = jnp.stack([tt, hh, ww], axis=0).astype(jnp.int32)   # (3, t*h*w)
    return jnp.broadcast_to(pos[:, None, :], (3, batch_size, t * h * w))
