"""Shared model primitives — pure-functional JAX, sharding-annotated.

Conventions:
  * params are dict pytrees of jnp arrays; initializers take an rng key.
  * activations run in cfg.dtype (bf16), matmuls accumulate in fp32 via
    preferred_element_type, norms/softmax in fp32.
  * every primitive takes logical-axis annotations from parallel.sharding.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import shard_constraint

__all__ = [
    "rms_norm", "init_rms_norm",
    "init_dense", "dense",
    "init_embedding", "embed", "unembed",
    "rope_freqs", "apply_rope", "apply_mrope",
    "init_attention", "attention", "decode_attention",
    "init_mlp", "mlp",
    "cross_entropy_loss",
]

Params = dict[str, Any]

# Dynamically-scoped matmul output dtype (preferred_element_type).  f32 by
# default; the bf16comm perf variant sets bf16 — on TPU the MXU still
# accumulates in f32 internally, this only narrows cross-shard partial sums
# and the backward all-reduces to bf16 (halving their bytes).  Norms, RoPE
# and softmax stay f32 regardless.
_PET = [jnp.float32]


class use_accum_dtype:
    def __init__(self, dtype):
        self.dtype = jnp.dtype(dtype)

    def __enter__(self):
        _PET.append(self.dtype)
        return self.dtype

    def __exit__(self, *exc):
        _PET.pop()
        return False


def pet():
    return _PET[-1]


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}

def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# dense
# --------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False) -> Params:
    scale = 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p

def dense(p: Params, x: jax.Array) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, p["w"],
                   preferred_element_type=pet())
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# embeddings
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"w": w.astype(dtype)}

def embed(p: Params, tokens: jax.Array, onehot: bool = False,
          chunk: int = 512) -> jax.Array:
    if onehot:
        # one-hot matmul: SPMD-native (plain contraction over vocab) where
        # a gather with (data,model)-sharded indices vs model-sharded table
        # forces GSPMD into involuntary full rematerialization (a
        # replicated (B, S, d) gather output).  Chunked over length so the
        # (chunk, V) one-hot slab stays ~100 MB.  ~2·B·S·V/shards extra
        # MXU flops — noise next to a transformer block.
        b, l = tokens.shape
        if l % chunk:
            chunk = l

        def body(_, tok_c):
            oh = jax.nn.one_hot(tok_c, p["w"].shape[0], dtype=p["w"].dtype)
            out_c = jnp.einsum("blv,vd->bld", oh, p["w"],
                               preferred_element_type=pet())
            return None, out_c.astype(p["w"].dtype)

        tok = jnp.moveaxis(tokens.reshape(b, l // chunk, chunk), 1, 0)
        _, out = jax.lax.scan(body, None, tok)
        out = jnp.moveaxis(out, 0, 1).reshape(b, l, p["w"].shape[1])
    else:
        out = jnp.take(p["w"], tokens, axis=0)
    return shard_constraint(out, ("activation_batch", "residual_length",
                                  "activation_embed"))

def unembed(p: Params, x: jax.Array) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, p["w"],
                        preferred_element_type=pet())
    return shard_constraint(logits, ("activation_batch", "activation_length",
                                     "activation_vocab"))


# --------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))

def _rope_cos_sin(positions: jax.Array, inv_freq: jax.Array):
    # positions: (..., L) -> cos/sin (..., L, head_dim/2)
    ang = positions[..., None].astype(jnp.float32) * inv_freq
    return jnp.cos(ang), jnp.sin(ang)

def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, L, H, D); positions: (B, L)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)
    cos, sin = _rope_cos_sin(positions, inv)       # (B, L, D/2)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)

def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: tuple[int, ...]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    positions: (3, B, L) — temporal / height / width position streams.
    ``sections`` splits head_dim/2 frequency slots among the 3 streams
    (e.g. (16, 24, 24) for head_dim 128).
    """
    d = x.shape[-1]
    if sum(sections) != d // 2:
        raise ValueError(f"mrope sections {sections} must sum to {d // 2}")
    inv = rope_freqs(d, theta)                     # (D/2,)
    # pick, per frequency slot, which positional stream drives it
    stream = np.repeat(np.arange(len(sections)), sections)   # (D/2,)
    pos_sel = jnp.take(positions, stream, axis=0)  # (D/2, B, L) gather streams
    pos_sel = jnp.moveaxis(pos_sel, 0, -1)         # (B, L, D/2)
    ang = pos_sel.astype(jnp.float32) * inv
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention (GQA, optional qk-norm / bias / M-RoPE / windowing)
# --------------------------------------------------------------------------

def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False,
                   qk_norm: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    s_q = 1.0 / math.sqrt(d_model)
    p: Params = {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads, head_dim)) * s_q).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads, head_dim)) * s_q).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads, head_dim)) * s_q).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads, head_dim, d_model))
               * (1.0 / math.sqrt(n_heads * head_dim))).astype(dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, head_dim), dtype=dtype)
        p["bk"] = jnp.zeros((n_kv_heads, head_dim), dtype=dtype)
        p["bv"] = jnp.zeros((n_kv_heads, head_dim), dtype=dtype)
    if qk_norm:
        p["q_norm"] = init_rms_norm(head_dim, dtype)
        p["k_norm"] = init_rms_norm(head_dim, dtype)
    return p


def attention_param_axes(qkv_bias: bool = False, qk_norm: bool = False) -> Params:
    p: Params = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if qkv_bias:
        p["bq"] = ("heads", "head_dim")
        p["bk"] = ("kv_heads", "head_dim")
        p["bv"] = ("kv_heads", "head_dim")
    if qk_norm:
        p["q_norm"] = {"scale": ("head_dim",)}
        p["k_norm"] = {"scale": ("head_dim",)}
    return p


def _project_qkv(p: Params, x: jax.Array, positions, theta,
                 qk_norm: bool, eps: float, mrope_sections=()):
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"],
                   preferred_element_type=pet()).astype(x.dtype)
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"],
                   preferred_element_type=pet()).astype(x.dtype)
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"],
                   preferred_element_type=pet()).astype(x.dtype)
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if qk_norm:
        q = rms_norm(p["q_norm"], q, eps)
        k = rms_norm(p["k_norm"], k, eps)
    if mrope_sections:
        q = apply_mrope(q, positions, theta, mrope_sections)
        k = apply_mrope(k, positions, theta, mrope_sections)
    else:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    q = shard_constraint(q, ("activation_batch", "activation_length",
                             "activation_heads", None))
    k = shard_constraint(k, ("activation_batch", "activation_length",
                             "activation_kv_heads", None))
    v = shard_constraint(v, ("activation_batch", "activation_length",
                             "activation_kv_heads", None))
    return q, k, v


def _sdpa(q, k, v, mask):
    """q: (B,Lq,H,D); k,v: (B,Lk,KV,D); GQA via head grouping."""
    b, lq, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, lq, kv, g, d)
    logits = jnp.einsum("blkgd,bmkd->bkglm", qg, k,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(d)
    logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkglm,bmkd->blkgd", w.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, lq, h, d).astype(q.dtype)


# Above this many score elements per head-group, attention switches to the
# kv-chunked online-softmax path (flash-style: O(L·chunk) live memory).
# On TPU the Pallas kernel (kernels/flash_attention.py) takes this role;
# the jnp scan below is its XLA-lowerable twin used by the dry-run.
_CHUNKED_SDPA_THRESHOLD = 4096 * 4096
_SDPA_CHUNK = 1024


def scan_unroll_of(cfg) -> bool | int:
    """lax.scan unroll argument honoring the dry-run cost probes."""
    return True if getattr(cfg, "probe_unroll", False) else 1


def remat_wrap(cfg, body):
    """Apply the configured activation-checkpoint policy to a scan body."""
    if not cfg.remat:
        return body
    if getattr(cfg, "remat_policy", "full") == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(body, policy=policy)


def _flash_fwd_core(qg, kc, vc, chunk, unroll):
    """qg: (B,Lq,KV,G,D); kc/vc: (B,NC,chunk,KV,D) -> out grouped + lse."""
    b, lq = qg.shape[0], qg.shape[1]
    kv, g, d = qg.shape[2], qg.shape[3], qg.shape[4]
    nc = kc.shape[1]
    scale = 1.0 / math.sqrt(d)
    rows = jnp.arange(lq)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        ci, kb, vb = xs
        s = jnp.einsum("blkgd,bmkd->bkglm", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        cols = ci * chunk + jnp.arange(chunk)
        causal = rows[:, None] >= cols[None, :]
        s = jnp.where(causal[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        upd = jnp.einsum("bkglm,bmkd->bkgld", p.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + upd
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv, g, lq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kv, g, lq), jnp.float32)
    a0 = jnp.zeros((b, kv, g, lq, d), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(nc), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        unroll=unroll)
    out_g = acc / l_f[..., None]                          # (B,KV,G,Lq,D) f32
    lse = m_f + jnp.log(l_f)                              # (B,KV,G,Lq)
    return out_g, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _sdpa_chunked_causal(q, k, v, chunk: int = _SDPA_CHUNK,
                         unroll: bool | int = 1):
    """Causal flash attention, kv-chunked online softmax with a flash-style
    custom VJP: the backward recomputes per-chunk probabilities from the
    saved log-sum-exp instead of letting scan-AD stack O(Lq·Lk) residuals
    (which would erase the memory win — measured 3×2.7 GB per layer at 4k
    before this VJP existed; see EXPERIMENTS.md §Perf).

    q: (B,Lq,H,D); k,v: (B,Lk,KV,D); Lq == Lk (self-attention prefill).
    """
    b, lq, h, d = q.shape
    lk, kv = k.shape[1], k.shape[2]
    g = h // kv
    nc = lk // chunk
    qg = q.reshape(b, lq, kv, g, d)
    kc = k.reshape(b, nc, chunk, kv, d)
    vc = v.reshape(b, nc, chunk, kv, d)
    out_g, _ = _flash_fwd_core(qg, kc, vc, chunk, unroll)
    out = jnp.moveaxis(out_g, 3, 1)                       # (B,KV,G,Lq,D)->(B,Lq,KV,G,D)
    return out.reshape(b, lq, h, d).astype(q.dtype)


def _sdpa_chunked_fwd(q, k, v, chunk, unroll):
    b, lq, h, d = q.shape
    lk, kv = k.shape[1], k.shape[2]
    g = h // kv
    nc = lk // chunk
    qg = q.reshape(b, lq, kv, g, d)
    kc = k.reshape(b, nc, chunk, kv, d)
    vc = v.reshape(b, nc, chunk, kv, d)
    out_g, lse = _flash_fwd_core(qg, kc, vc, chunk, unroll)
    out = jnp.moveaxis(out_g, 3, 1).reshape(b, lq, h, d).astype(q.dtype)
    return out, (q, k, v, out, lse)


def _sdpa_chunked_bwd(chunk, unroll, res, dout):
    q, k, v, out, lse = res
    b, lq, h, d = q.shape
    lk, kv = k.shape[1], k.shape[2]
    g = h // kv
    nc = lk // chunk
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, lq, kv, g, d).astype(jnp.float32)
    og = jnp.moveaxis(dout.reshape(b, lq, kv, g, d), 1, 3).astype(jnp.float32)
    outg = jnp.moveaxis(out.reshape(b, lq, kv, g, d), 1, 3).astype(jnp.float32)
    # delta[r] = sum_d out[r,d] * dout[r,d]  (flash-bwd row correction)
    delta = jnp.sum(outg * og, axis=-1)                   # (B,KV,G,Lq)
    kc = k.reshape(b, nc, chunk, kv, d)
    vc = v.reshape(b, nc, chunk, kv, d)
    rows = jnp.arange(lq)

    def body(dq_acc, xs):
        ci, kb, vb = xs
        s = jnp.einsum("blkgd,bmkd->bkglm", qg.astype(q.dtype), kb,
                       preferred_element_type=jnp.float32) * scale
        cols = ci * chunk + jnp.arange(chunk)
        causal = rows[:, None] >= cols[None, :]
        s = jnp.where(causal[None, None, None], s, -1e30)
        p = jnp.exp(s - lse[..., None])                   # (B,KV,G,Lq,chunk)
        dv_c = jnp.einsum("bkglm,bkgld->bmkd", p.astype(og.dtype), og,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bkgld,bmkd->bkglm", og.astype(vb.dtype), vb,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq_c = jnp.einsum("bkglm,bmkd->blkgd", ds.astype(kb.dtype), kb,
                          preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bkglm,blkgd->bmkd", ds.astype(q.dtype),
                          qg.astype(q.dtype),
                          preferred_element_type=jnp.float32)
        return dq_acc + dq_c, (dk_c, dv_c)

    dq0 = jnp.zeros((b, lq, kv, g, d), jnp.float32)
    dq, (dk_c, dv_c) = jax.lax.scan(
        body, dq0,
        (jnp.arange(nc), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
        unroll=unroll)
    dk = jnp.moveaxis(dk_c, 0, 1).reshape(b, lk, kv, d).astype(k.dtype)
    dv = jnp.moveaxis(dv_c, 0, 1).reshape(b, lk, kv, d).astype(v.dtype)
    return dq.reshape(b, lq, h, d).astype(q.dtype), dk, dv


_sdpa_chunked_causal.defvjp(_sdpa_chunked_fwd, _sdpa_chunked_bwd)


def attention(p: Params, x: jax.Array, positions: jax.Array, *,
              theta: float, qk_norm: bool = False, eps: float = 1e-6,
              mrope_sections: tuple[int, ...] = (),
              kv_override: tuple[jax.Array, jax.Array] | None = None,
              causal: bool = True, window: int = 0,
              unroll: bool | int = 1,
              chunk_threshold: int = _CHUNKED_SDPA_THRESHOLD) -> jax.Array:
    """Full (prefill/train) attention.  kv_override enables cross-attention."""
    q, k, v = _project_qkv(p, x, positions, theta, qk_norm, eps, mrope_sections)
    if kv_override is not None:
        k, v = kv_override
    lq, lk = q.shape[1], k.shape[1]
    plain_causal = causal and kv_override is None and (window == 0 or window >= lk)
    if (plain_causal and lq == lk and lq * lk > chunk_threshold
            and lk % _SDPA_CHUNK == 0):
        out = _sdpa_chunked_causal(q, k, v, unroll=unroll)
    else:
        if causal and kv_override is None:
            idx_q = jnp.arange(lq)[:, None]
            idx_k = jnp.arange(lk)[None, :]
            mask = idx_k <= idx_q
            if window > 0:
                mask &= idx_k > idx_q - window
            mask = mask[None, None, None, :, :]
        else:
            mask = jnp.ones((1, 1, 1, lq, lk), dtype=bool)
        out = _sdpa(q, k, v, mask)
    y = jnp.einsum("blhd,hdm->blm", out, p["wo"],
                   preferred_element_type=pet()).astype(x.dtype)
    return shard_constraint(y, ("activation_batch", "residual_length",
                                "activation_embed"))


def prefill_attention_kv(p: Params, x, positions, *, theta, qk_norm=False,
                         eps=1e-6, mrope_sections=()):
    """Return (k, v) for cache seeding."""
    _, k, v = _project_qkv(p, x, positions, theta, qk_norm, eps, mrope_sections)
    return k, v


def decode_attention(p: Params, x: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, cache_len: jax.Array,
                     positions: jax.Array, *, theta: float,
                     qk_norm: bool = False, eps: float = 1e-6,
                     mrope_sections: tuple[int, ...] = (),
                     window: int = 0,
                     write_pos: jax.Array | None = None):
    """One decode step.  x: (B,1,d); cache_k/v: (B,S,KV,D); cache_len: (B,).

    ``write_pos`` overrides the slot the new KV lands in (ring-buffer
    caches pass cache_len % S; RoPE is applied before caching so key order
    in the buffer is irrelevant).  Returns (y, new_cache_k, new_cache_v).
    """
    q, k, v = _project_qkv(p, x, positions, theta, qk_norm, eps, mrope_sections)
    b, s = cache_k.shape[0], cache_k.shape[1]
    wp = cache_len if write_pos is None else write_pos
    # scatter-write only the touched slot — a one-hot multiply would
    # read+rewrite the full (B,S,KV,D) cache every decode step (measured
    # ~2 cache-sizes of HBM traffic per layer; see EXPERIMENTS.md §Perf)
    bidx = jnp.arange(b)
    cache_k = cache_k.at[bidx, wp].set(k[:, 0], mode="drop")
    cache_v = cache_v.at[bidx, wp].set(v[:, 0], mode="drop")
    idx = jnp.arange(s)[None, :]
    mask = idx <= cache_len[:, None]
    if window > 0:
        mask &= idx > (cache_len[:, None] - window)
    mask = mask[:, None, None, None, :]                          # (B,1,1,1,S)
    out = _sdpa(q, cache_k, cache_v, mask)
    y = jnp.einsum("blhd,hdm->blm", out, p["wo"],
                   preferred_element_type=pet()).astype(x.dtype)
    return y, cache_k, cache_v


# --------------------------------------------------------------------------
# MLP (SwiGLU)
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(ks[0], (d_model, d_ff)) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ks[1], (d_model, d_ff)) * s_in).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (d_ff, d_model)) * s_out).astype(dtype),
    }

MLP_AXES = {
    "w_gate": ("embed", "mlp"),
    "w_up": ("embed", "mlp"),
    "w_down": ("mlp", "embed"),
}

def mlp(p: Params, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"],
                   preferred_element_type=pet())
    u = jnp.einsum("...d,df->...f", x, p["w_up"],
                   preferred_element_type=pet())
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    h = shard_constraint(h, ("activation_batch", "activation_length",
                             "activation_mlp"))
    y = jnp.einsum("...f,fd->...d", h, p["w_down"],
                   preferred_element_type=pet()).astype(x.dtype)
    return shard_constraint(y, ("activation_batch", "residual_length",
                                "activation_embed"))


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean token cross-entropy in fp32. logits (B,L,V), labels (B,L)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def fused_unembed_ce(emb: Params, h: jax.Array, labels: jax.Array,
                     chunk: int = 512, unroll: bool | int = 1) -> jax.Array:
    """Fused unembed + cross-entropy, chunked over length: the (B, L, V)
    logits tensor is never materialized — each scan step computes one
    (B, chunk, V) slab, reduces it to (lse, gold) and discards it.  The
    Megatron fused-loss pattern; removes ~B*L*V*(2+4) bytes of HBM
    residency for free (the slabs were going to be computed anyway)."""
    b, l, d = h.shape
    if l % chunk:
        return cross_entropy_loss(unembed(emb, h), labels)
    hc = h.reshape(b, l // chunk, chunk, d)
    lc = labels.reshape(b, l // chunk, chunk)

    def body(acc, xs):
        h_c, lab_c = xs                                # (B,chunk,d),(B,chunk)
        logits = jnp.einsum("bld,vd->blv", h_c, emb["w"],
                            preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab_c[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                            (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(lc, 1, 0)),
                            unroll=unroll)
    return total / (b * l)
