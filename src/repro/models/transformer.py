"""Dense decoder-only transformer LM (qwen2.5 / qwen1.5 / qwen3 / granite
flavors: GQA, optional QKV bias, optional qk-norm).

Layer params are stacked along a leading "layers" axis and executed with
``jax.lax.scan`` so the HLO contains a single block body regardless of
depth (critical for CPU-backend compile times at 80 layers, and the
standard production pattern on TPU).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.parallel.sharding import shard_constraint

Params = dict[str, Any]


# --------------------------------------------------------------------------
# single block
# --------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    dtype = jnp.dtype(cfg.param_dtype)
    return {
        "ln1": L.init_rms_norm(cfg.d_model, dtype),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, dtype,
            qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm,
        ),
        "ln2": L.init_rms_norm(cfg.d_model, dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def block_axes(cfg: ModelConfig) -> Params:
    return {
        "ln1": {"scale": (None,)},
        "attn": L.attention_param_axes(cfg.qkv_bias, cfg.qk_norm),
        "ln2": {"scale": (None,)},
        "mlp": dict(L.MLP_AXES),
    }


def block_apply(p: Params, h: jax.Array, positions: jax.Array,
                cfg: ModelConfig) -> jax.Array:
    a = L.attention(
        p["attn"], L.rms_norm(p["ln1"], h, cfg.norm_eps), positions,
        theta=cfg.rope_theta, qk_norm=cfg.qk_norm, eps=cfg.norm_eps,
        mrope_sections=cfg.mrope_sections, causal=True,
        unroll=L.scan_unroll_of(cfg),
        chunk_threshold=cfg.attn_chunk_threshold,
    )
    h = h + a
    h = h + L.mlp(p["mlp"], L.rms_norm(p["ln2"], h, cfg.norm_eps))
    return h


def block_decode(p: Params, h, ck, cv, cache_len, positions, cfg: ModelConfig):
    a, ck, cv = L.decode_attention(
        p["attn"], L.rms_norm(p["ln1"], h, cfg.norm_eps), ck, cv, cache_len,
        positions, theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
        eps=cfg.norm_eps, mrope_sections=cfg.mrope_sections,
        window=cfg.attn_window,
    )
    h = h + a
    h = h + L.mlp(p["mlp"], L.rms_norm(p["ln2"], h, cfg.norm_eps))
    return h, ck, cv


# --------------------------------------------------------------------------
# stack machinery (shared with moe.py / vlm.py)
# --------------------------------------------------------------------------

def init_stacked(key, cfg: ModelConfig, init_one=init_block) -> Params:
    keys = jax.random.split(key, cfg.n_layers)
    return jax.vmap(lambda k: init_one(k, cfg))(keys)


def stacked_axes(cfg: ModelConfig, one_axes=block_axes) -> Params:
    """Prepend the scan ("layers") axis to every leaf."""
    base = one_axes(cfg)
    return jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        base,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def scan_stack(stacked: Params, h: jax.Array, positions: jax.Array,
               cfg: ModelConfig, apply_one=block_apply) -> jax.Array:
    def body(carry, lp):
        return apply_one(lp, carry, positions, cfg), None

    body = L.remat_wrap(cfg, body)
    h, _ = lax.scan(body, h, stacked, unroll=L.scan_unroll_of(cfg))
    return h


def scan_stack_decode(stacked: Params, cache: Params, h, cache_len, positions,
                      cfg: ModelConfig, decode_one=block_decode):
    def body(carry, xs):
        lp, ck, cv = xs
        h2, ck, cv = decode_one(lp, carry, ck, cv, cache_len, positions, cfg)
        return h2, (ck, cv)

    h, (nk, nv) = lax.scan(body, h, (stacked, cache["k"], cache["v"]),
                           unroll=L.scan_unroll_of(cfg))
    return h, {"k": nk, "v": nv}


# --------------------------------------------------------------------------
# whole LM
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig, init_one=init_block) -> Params:
    k_e, k_l, k_u = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "embedding": L.init_embedding(k_e, cfg.padded_vocab, cfg.d_model, dtype),
        "layers": init_stacked(k_l, cfg, init_one),
        "final_norm": L.init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embedding(k_u, cfg.padded_vocab, cfg.d_model, dtype)
    return p


def param_axes(cfg: ModelConfig, one_axes=block_axes) -> Params:
    p: Params = {
        "embedding": {"w": ("vocab", "table_embed")},
        "layers": stacked_axes(cfg, one_axes),
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": ("vocab", "table_embed")}
    return p


def _embed_in(params, batch, cfg):
    if "embeds" in batch:                      # modality-frontend stub (vlm)
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        h = shard_constraint(h, ("activation_batch", "activation_length",
                                 "activation_embed"))
    else:
        h = L.embed(params["embedding"], batch["tokens"],
                    onehot=cfg.embed_onehot)
    return h


def _positions_of(batch, cfg):
    if "positions" in batch:
        return batch["positions"]
    tokens = batch.get("tokens", batch.get("embeds"))
    b, s = tokens.shape[0], tokens.shape[1]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos, (3, b, s))
    return pos


def forward(params: Params, batch: Params, cfg: ModelConfig,
            apply_one=block_apply) -> jax.Array:
    """Train/prefill logits: (B, L, V)."""
    h = _embed_in(params, batch, cfg)
    positions = _positions_of(batch, cfg)
    h = scan_stack(params["layers"], h, positions, cfg, apply_one)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(emb, h)


def loss_fn(params: Params, batch: Params, cfg: ModelConfig,
            apply_one=block_apply) -> jax.Array:
    if cfg.fused_ce and "mask" not in batch:
        h = _embed_in(params, batch, cfg)
        positions = _positions_of(batch, cfg)
        h = scan_stack(params["layers"], h, positions, cfg, apply_one)
        h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
        emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
        return L.fused_unembed_ce(emb, h, batch["labels"],
                                  unroll=L.scan_unroll_of(cfg))
    logits = forward(params, batch, cfg, apply_one)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    kv, d = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_len, kv, d)
    return {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
        "len": jnp.zeros((batch,), dtype=jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> Params:
    ax = ("layers", "cache_batch", "cache_length", "cache_kv_heads",
          "cache_head_dim")
    return {"k": ax, "v": ax, "len": ("cache_batch",)}


def prefill(params: Params, batch: Params, cfg: ModelConfig,
            max_len: int, apply_one=block_apply):
    """Run the prompt, fill the KV cache, return last-token logits + cache."""
    h = _embed_in(params, batch, cfg)
    positions = _positions_of(batch, cfg)
    b, s = h.shape[0], h.shape[1]

    ks, vs = [], []

    def body(carry, lp):
        hh = carry
        x = L.rms_norm(lp["ln1"], hh, cfg.norm_eps)
        k, v = L.prefill_attention_kv(
            lp["attn"], x, positions, theta=cfg.rope_theta,
            qk_norm=cfg.qk_norm, eps=cfg.norm_eps,
            mrope_sections=cfg.mrope_sections)
        hh = apply_one(lp, hh, positions, cfg)
        return hh, (k, v)

    body = L.remat_wrap(cfg, body)
    h, (k_all, v_all) = lax.scan(body, h, params["layers"],
                                 unroll=L.scan_unroll_of(cfg))

    pad = max_len - s
    k_all = jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_all = jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_all, "v": v_all,
             "len": jnp.full((b,), s, dtype=jnp.int32)}
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h[:, -1:, :])
    return logits, cache


def decode_step(params: Params, cache: Params, batch: Params,
                cfg: ModelConfig, decode_one=block_decode):
    """One token for every sequence.  batch["tokens"]: (B, 1)."""
    h = _embed_in(params, batch, cfg)
    b = h.shape[0]
    cache_len = cache["len"]
    pos = cache_len[:, None].astype(jnp.int32)          # (B,1)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos, (3, b, 1))
    h, new_kv = scan_stack_decode(params["layers"], cache, h, cache_len, pos,
                                  cfg, decode_one)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h)
    new_cache = {"k": new_kv["k"], "v": new_kv["v"], "len": cache_len + 1}
    return logits, new_cache
