"""Zamba2-style hybrid: a Mamba2 backbone with ONE shared attention block
(arXiv:2411.15242) applied every ``shared_attn_every`` mamba layers.

The shared block's weights are reused at every invocation (the zamba2
signature); each invocation keeps its own KV cache.  Following the paper,
the shared block consumes concat(h, h0) — the current hidden state and the
original embeddings — projected back to d_model.

Mamba layers are scanned in segments of ``shared_attn_every``; the shared
block sits between segments, so the HLO holds one mamba body + one
attention body regardless of depth.

For long_500k decode the shared block's KV cache is windowed to
cfg.attn_window (32k) — attention is O(window) per token while the SSM
carries unbounded context, which is what makes this arch long-context
runnable (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M
from repro.parallel.sharding import shard_constraint

Params = dict[str, Any]


def _segments(cfg: ModelConfig) -> list[int]:
    """Mamba-layer counts per segment; a shared-attn invocation follows each
    full segment."""
    every = cfg.shared_attn_every or cfg.n_layers
    full, leftover = divmod(cfg.n_layers, every)
    return [every] * full + ([leftover] if leftover else [])


def n_shared_invocations(cfg: ModelConfig) -> int:
    every = cfg.shared_attn_every or cfg.n_layers
    return cfg.n_layers // every


def init_shared_block(key, cfg: ModelConfig) -> Params:
    k0, k1, k2 = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    return {
        "in_proj": {"w": (jax.random.normal(k0, (2 * d, d))
                          * (1.0 / math.sqrt(2 * d))).astype(dtype)},
        "ln1": L.init_rms_norm(d, dtype),
        "attn": L.init_attention(k1, d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.resolved_head_dim, dtype),
        "ln2": L.init_rms_norm(d, dtype),
        "mlp": L.init_mlp(k2, d, cfg.d_ff, dtype),
    }


def shared_block_axes(cfg: ModelConfig) -> Params:
    return {
        "in_proj": {"w": ("embed", None)},
        "ln1": {"scale": (None,)},
        "attn": L.attention_param_axes(),
        "ln2": {"scale": (None,)},
        "mlp": dict(L.MLP_AXES),
    }


def shared_block_apply(p: Params, h, h0, positions, cfg: ModelConfig):
    x = jnp.concatenate([h, h0], axis=-1)
    x = jnp.einsum("bld,dk->blk", x, p["in_proj"]["w"],
                   preferred_element_type=jnp.float32).astype(h.dtype)
    a = L.attention(p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps),
                    positions, theta=cfg.rope_theta, eps=cfg.norm_eps,
                    causal=True, window=cfg.attn_window,
                    unroll=L.scan_unroll_of(cfg),
                    chunk_threshold=cfg.attn_chunk_threshold)
    x = x + a
    x = x + L.mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps))
    return h + x


def shared_block_decode(p: Params, h, h0, ck, cv, cache_len, positions,
                        cfg: ModelConfig):
    x = jnp.concatenate([h, h0], axis=-1)
    x = jnp.einsum("bld,dk->blk", x, p["in_proj"]["w"],
                   preferred_element_type=jnp.float32).astype(h.dtype)
    # The KV buffer is sized to attn_window (ring buffer): once cache_len
    # exceeds it, wrap the write slot; the full buffer is then the window,
    # so no extra window masking is needed.
    buf = ck.shape[1]
    a, ck, cv = L.decode_attention(
        p["attn"], L.rms_norm(p["ln1"], x, cfg.norm_eps), ck, cv, cache_len,
        positions, theta=cfg.rope_theta, eps=cfg.norm_eps,
        write_pos=cache_len % buf)
    x = x + a
    x = x + L.mlp(p["mlp"], L.rms_norm(p["ln2"], x, cfg.norm_eps))
    return h + x, ck, cv


def shared_block_kv(p: Params, h, h0, positions, cfg: ModelConfig):
    x = jnp.concatenate([h, h0], axis=-1)
    x = jnp.einsum("bld,dk->blk", x, p["in_proj"]["w"],
                   preferred_element_type=jnp.float32).astype(h.dtype)
    return L.prefill_attention_kv(p["attn"],
                                  L.rms_norm(p["ln1"], x, cfg.norm_eps),
                                  positions, theta=cfg.rope_theta,
                                  eps=cfg.norm_eps)


# --------------------------------------------------------------------------
# assembly
# --------------------------------------------------------------------------

def init(key, cfg: ModelConfig) -> Params:
    k_e, k_m, k_s, k_u = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(k_m, cfg.n_layers)
    p: Params = {
        "embedding": L.init_embedding(k_e, cfg.padded_vocab, cfg.d_model, dtype),
        "mamba": jax.vmap(lambda k: M.init_block(k, cfg))(keys),
        "shared": init_shared_block(k_s, cfg),
        "final_norm": L.init_rms_norm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.init_embedding(k_u, cfg.padded_vocab, cfg.d_model, dtype)
    return p


def param_axes(cfg: ModelConfig) -> Params:
    mam = jax.tree.map(lambda ax: ("layers",) + tuple(ax), M.block_axes(cfg),
                       is_leaf=lambda x: isinstance(x, tuple))
    p: Params = {
        "embedding": {"w": ("vocab", "table_embed")},
        "mamba": mam,
        "shared": shared_block_axes(cfg),
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        p["unembed"] = {"w": ("vocab", "table_embed")}
    return p


def _slice_stacked(tree: Params, start: int, count: int) -> Params:
    return jax.tree.map(lambda x: lax.slice_in_dim(x, start, start + count, axis=0),
                        tree)


def _scan_mamba(stacked, h, cfg, collect_states=False):
    def body(carry, lp):
        if collect_states:
            hh, (st, tail) = M.block_apply(lp, carry, None, cfg,
                                           return_states=True)
            return hh, (st, tail)
        return M.block_apply(lp, carry, None, cfg), None

    body = L.remat_wrap(cfg, body)
    return lax.scan(body, h, stacked, unroll=L.scan_unroll_of(cfg))


def forward(params, batch, cfg: ModelConfig):
    h = L.embed(params["embedding"], batch["tokens"], onehot=cfg.embed_onehot)
    h0 = h
    bsz, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    off = 0
    for seg_idx, seg in enumerate(_segments(cfg)):
        stacked = _slice_stacked(params["mamba"], off, seg)
        h, _ = _scan_mamba(stacked, h, cfg)
        off += seg
        if seg == (cfg.shared_attn_every or cfg.n_layers):
            h = shared_block_apply(params["shared"], h, h0, positions, cfg)
    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(emb, h)


def loss_fn(params, batch, cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy_loss(logits, batch["labels"], batch.get("mask"))


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    d_in, g, n, h, conv_dim = M._dims(cfg)
    dtype = jnp.dtype(cfg.dtype)
    inv = n_shared_invocations(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    cache_len = min(max_len, cfg.attn_window) if cfg.attn_window else max_len
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, h, d_in // h, n), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_kernel - 1, conv_dim),
                          dtype=dtype),
        "k": jnp.zeros((inv, batch, cache_len, kv, hd), dtype=dtype),
        "v": jnp.zeros((inv, batch, cache_len, kv, hd), dtype=dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> Params:
    return {
        "ssm": ("layers", "cache_batch", "activation_heads", None, None),
        "conv": ("layers", "cache_batch", None, "activation_mlp"),
        "k": ("layers", "cache_batch", "cache_length", "cache_kv_heads",
              "cache_head_dim"),
        "v": ("layers", "cache_batch", "cache_length", "cache_kv_heads",
              "cache_head_dim"),
        "len": ("cache_batch",),
    }


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    h = L.embed(params["embedding"], batch["tokens"], onehot=cfg.embed_onehot)
    h0 = h
    bsz, s = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (bsz, s))
    cache_len = min(max_len, cfg.attn_window) if cfg.attn_window else max_len

    ssm_states, conv_tails, ks, vs = [], [], [], []
    off = 0
    for seg in _segments(cfg):
        stacked = _slice_stacked(params["mamba"], off, seg)
        h, (st, tail) = _scan_mamba(stacked, h, cfg, collect_states=True)
        ssm_states.append(st)
        conv_tails.append(tail)
        off += seg
        if seg == (cfg.shared_attn_every or cfg.n_layers):
            k, v = shared_block_kv(params["shared"], h, h0, positions, cfg)
            pad = cache_len - k.shape[1]
            if pad >= 0:
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
            else:  # windowed: keep the most recent ``cache_len`` entries
                k, v = k[:, -cache_len:], v[:, -cache_len:]
            ks.append(k)
            vs.append(v)
            h = shared_block_apply(params["shared"], h, h0, positions, cfg)

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h[:, -1:, :])
    kv_hd = (cfg.n_kv_heads, cfg.resolved_head_dim)
    empty = jnp.zeros((0, bsz, cache_len) + kv_hd, dtype=h.dtype)
    cache = {
        "ssm": jnp.concatenate(ssm_states, axis=0).astype(jnp.float32),
        "conv": jnp.concatenate(conv_tails, axis=0),
        "k": jnp.stack(ks, axis=0) if ks else empty,
        "v": jnp.stack(vs, axis=0) if vs else empty,
        "len": jnp.full((bsz,), min(s, cache_len), jnp.int32),
    }
    return logits, cache


def decode_step(params, cache, batch, cfg: ModelConfig):
    h = L.embed(params["embedding"], batch["tokens"])
    h0 = h
    bsz = h.shape[0]
    cache_len = cache["len"]
    pos = cache_len[:, None].astype(jnp.int32)

    new_ssm, new_conv, new_k, new_v = [], [], [], []
    off, inv = 0, 0
    for seg in _segments(cfg):
        stacked = _slice_stacked(params["mamba"], off, seg)
        ssm_seg = lax.slice_in_dim(cache["ssm"], off, off + seg, axis=0)
        conv_seg = lax.slice_in_dim(cache["conv"], off, off + seg, axis=0)

        def body(carry, xs):
            lp, st, tail = xs
            hh, st2, tail2 = M.block_decode(lp, carry, st, tail, cfg)
            return hh, (st2, tail2)

        h, (st2, tail2) = lax.scan(body, h, (stacked, ssm_seg, conv_seg),
                                   unroll=L.scan_unroll_of(cfg))
        new_ssm.append(st2)
        new_conv.append(tail2)
        off += seg
        if seg == (cfg.shared_attn_every or cfg.n_layers):
            h, ck, cv = shared_block_decode(
                params["shared"], h, h0, cache["k"][inv], cache["v"][inv],
                cache_len, pos, cfg)
            new_k.append(ck)
            new_v.append(cv)
            inv += 1

    h = L.rms_norm(params["final_norm"], h, cfg.norm_eps)
    emb = params["embedding"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(emb, h)
    new_cache = {
        "ssm": jnp.concatenate(new_ssm, axis=0),
        "conv": jnp.concatenate(new_conv, axis=0),
        "k": jnp.stack(new_k, axis=0) if new_k else cache["k"],
        "v": jnp.stack(new_v, axis=0) if new_v else cache["v"],
        "len": cache_len + 1,
    }
    return logits, new_cache
