from .pipeline import (  # noqa: F401
    fcnn_classification_dataset,
    token_stream,
    Batcher,
)
