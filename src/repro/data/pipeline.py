"""Deterministic synthetic datasets + a sharded batcher.

The paper trains on fashion-mnist / cifar-10 (Table 6).  Offline, we
generate class-conditional Gaussian-mixture images with the same tensor
shapes (784- or 1024-dim inputs, 10 classes) — learnable structure so the
end-to-end examples show loss decreasing, deterministic so tests are
stable.  LM token streams are Zipf-distributed with injected bigram
structure for the same reason.

The Batcher shards each host batch over the mesh's data axes via
jax.device_put with a NamedSharding (the production input path: per-host
feed then device layout).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def fcnn_classification_dataset(
    n_samples: int, input_dim: int = 784, n_classes: int = 10, seed: int = 0,
    class_sep: float = 2.0,
) -> tuple[np.ndarray, np.ndarray]:
    """Gaussian-mixture stand-in for fashion-mnist/cifar (shapes match)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, input_dim)).astype(np.float32)
    centers *= class_sep / np.linalg.norm(centers, axis=1, keepdims=True)
    y = rng.integers(0, n_classes, size=n_samples)
    x = centers[y] + rng.normal(size=(n_samples, input_dim)).astype(np.float32)
    return x.astype(np.float32), y.astype(np.int32)


def token_stream(
    n_tokens: int, vocab: int, seed: int = 0, zipf_a: float = 1.2,
) -> np.ndarray:
    """Zipf unigrams + deterministic bigram structure (v -> (v*7+3) % vocab
    with prob .5) so an LM can reduce loss."""
    rng = np.random.default_rng(seed)
    base = rng.zipf(zipf_a, size=n_tokens).astype(np.int64) % vocab
    out = base.copy()
    follow = rng.random(n_tokens) < 0.5
    out[1:][follow[1:]] = (out[:-1][follow[1:]] * 7 + 3) % vocab
    return out.astype(np.int32)


@dataclasses.dataclass
class Batcher:
    """Iterates device-laid-out batches; resumable via ``state`` (step)."""

    data: dict[str, np.ndarray]
    batch_size: int
    mesh: Mesh | None = None
    batch_axes: tuple[str, ...] | None = ("data",)
    step: int = 0

    def __iter__(self) -> Iterator[dict[str, jax.Array]]:
        return self

    def _spec(self, arr: np.ndarray) -> P:
        axes = tuple(a for a in (self.batch_axes or ())
                     if self.mesh and a in self.mesh.axis_names)
        return P(axes if axes else None,
                 *([None] * (arr.ndim - 1)))

    def __next__(self) -> dict[str, jax.Array]:
        n = len(next(iter(self.data.values())))
        start = (self.step * self.batch_size) % n
        idx = (np.arange(self.batch_size) + start) % n
        self.step += 1
        out = {}
        for k, v in self.data.items():
            b = v[idx]
            if self.mesh is not None:
                out[k] = jax.device_put(
                    b, NamedSharding(self.mesh, self._spec(b)))
            else:
                out[k] = jnp.asarray(b)
        return out

    # --- checkpointable state ---
    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict) -> None:
        self.step = int(state["step"])
