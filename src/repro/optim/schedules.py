"""Learning-rate schedules (step -> lr), jittable."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(lr: float, steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(jnp.asarray(step, jnp.float32) / steps, 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup: int, steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(1, steps - warmup), final_frac)
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(1, warmup)
        return jnp.where(step < warmup, warm, cos(step - warmup))
    return fn
