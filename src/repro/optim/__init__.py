from .optimizers import (  # noqa: F401
    Optimizer,
    adam,
    adamw,
    sgd,
    momentum,
    clip_by_global_norm,
)
from .schedules import constant, cosine_decay, linear_warmup_cosine  # noqa: F401
