"""Minimal pytree optimizers (pure JAX, optax-style API).

Optimizer state mirrors the parameter pytree, so it inherits the exact
parameter sharding (FSDP'd moments for free).  Moments are kept in fp32
regardless of the parameter dtype (bf16-safe training).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params, jax.Array | float], tuple[Params, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def _f32_like(p):
    return jnp.zeros(p.shape, jnp.float32)


def sgd(lr: Callable[[jax.Array], jax.Array] | float) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {}

    def update(grads, state, params, step):
        eta = lr_fn(step)
        new = jax.tree.map(
            lambda p, g: (p.astype(jnp.float32) - eta * g.astype(jnp.float32)
                          ).astype(p.dtype),
            params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": jax.tree.map(_f32_like, params)}

    def update(grads, state, params, step):
        eta = lr_fn(step)
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                               m, grads)
        else:
            upd = m
        new = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - eta * u).astype(p.dtype),
            params, upd)
        return new, {"m": m}

    return Optimizer(init, update)


def _adam_core(lr_fn, b1, b2, eps, weight_decay):
    def init(params):
        return {
            "m": jax.tree.map(_f32_like, params),
            "v": jax.tree.map(_f32_like, params),
        }

    def update(grads, state, params, step):
        step = jnp.asarray(step, jnp.float32) + 1.0
        eta = lr_fn(step)
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        mhat_scale = 1.0 / (1.0 - b1 ** step)
        vhat_scale = 1.0 / (1.0 - b2 ** step)

        def upd(p, m_, v_):
            u = (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * u).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)
    return _adam_core(lr_fn, b1, b2, eps, 0.0)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)
    return _adam_core(lr_fn, b1, b2, eps, weight_decay)


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jax.Array]:
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm
