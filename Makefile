# Developer gate — the same checks the PR driver runs.
#
#   make verify       tier-1 pytest suite
#   make bench-smoke  fast sanity smoke (table7 + the softmax/xent
#                     microbench, so the fused-loss path is exercised)
#   make bench-json   full benchmark sweep -> BENCH_fcnn.json
#                     (includes softmax_xent_microbench by default)
#   make bench-gate   regression gate: fresh sweep diffed against the
#                     committed BENCH_fcnn.json — fails on paper-claim
#                     regressions or >20% median microbench speedup drop
#   make fault-smoke  seeded device-loss replan-resume scenario on the
#                     8-device CPU ring (the CI fault-smoke job)
#   make serve-smoke  steady + burst traffic presets through the
#                     continuous-batching serving engine on the smoke
#                     config (the CI serve-smoke job)
#   make lint         repo lint (tools/lint_repro.py): deprecated-shim
#                     calls, numpy.random in jitted bodies, kernel
#                     oracle-test coverage
#   make bench-refresh intentional baseline refresh: re-runs the sweep
#                     and rewrites BENCH_fcnn.json with a history snapshot
#                     of the old baseline appended

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify bench-smoke bench-json bench-gate bench-refresh \
        fault-smoke serve-smoke lint

verify:
	$(PY) -m pytest -x -q

lint:
	$(PY) tools/lint_repro.py

fault-smoke:
	$(PY) examples/elastic_restart.py
	$(PY) -m benchmarks.run --only fault_injection_bench

serve-smoke:
	$(PY) -m repro.launch.serve --arch qwen3-14b --smoke \
		--scenario steady --requests 8 --slots 3 --seed 0
	$(PY) -m repro.launch.serve --arch qwen3-14b --smoke \
		--scenario burst --requests 12 --slots 3 --seed 0

bench-smoke:
	$(PY) -m benchmarks.run --only table7_prediction
	$(PY) -m benchmarks.run --only softmax_xent_microbench

bench-json:
	$(PY) -m benchmarks.run --json BENCH_fcnn.json

bench-gate:
	$(PY) -m benchmarks.gate --baseline BENCH_fcnn.json

bench-refresh:
	$(PY) -m benchmarks.gate --baseline BENCH_fcnn.json --refresh
