# Developer gate — the same checks the PR driver runs.
#
#   make verify       tier-1 pytest suite
#   make bench-smoke  fast sanity smoke (table7 + the softmax/xent
#                     microbench, so the fused-loss path is exercised)
#   make bench-json   full benchmark sweep -> BENCH_fcnn.json
#                     (includes softmax_xent_microbench by default)
#   make bench-gate   regression gate: fresh sweep diffed against the
#                     committed BENCH_fcnn.json — fails on paper-claim
#                     regressions or >20% microbench speedup drop

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify bench-smoke bench-json bench-gate

verify:
	$(PY) -m pytest -x -q

bench-smoke:
	$(PY) -m benchmarks.run --only table7_prediction
	$(PY) -m benchmarks.run --only softmax_xent_microbench

bench-json:
	$(PY) -m benchmarks.run --json BENCH_fcnn.json

bench-gate:
	$(PY) -m benchmarks.gate --baseline BENCH_fcnn.json
