# Developer gate — the same checks the PR driver runs.
#
#   make verify       tier-1 pytest suite
#   make bench-smoke  one fast benchmark (table7) as a sanity smoke
#   make bench-json   full benchmark sweep -> BENCH_fcnn.json

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify bench-smoke bench-json

verify:
	$(PY) -m pytest -x -q

bench-smoke:
	$(PY) -m benchmarks.run --only table7_prediction

bench-json:
	$(PY) -m benchmarks.run --json BENCH_fcnn.json
