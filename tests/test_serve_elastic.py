"""Lemma-1 elastic autoscaling for serving (ISSUE 10 tentpole pin).

Unit level: ``ServeAutoscaler`` prices every transition with the real
``runtime.elastic.ElasticPlanner`` (Lemma-1 plan + period-program compile
+ static validation on the survivors), shrinks the decode batch by the
replanned epoch-throughput ratio on device loss, and grows it toward
capacity on sustained SLO violations.

End to end (the acceptance scenario): the seeded device-loss-mid-decode
preset on the real smoke model completes with a replan and restarts, and
every request's token stream is bit-identical to a no-fault run of the
same trace — greedy decode is a pure function of the prompt, so elastic
transitions cost latency, never tokens (the serving analogue of
tests/test_fault_recovery.py).
"""

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.serve.elastic import ReplanDecision, ServeAutoscaler
from repro.serve.runner import JaxModelRunner
from repro.serve.scheduler import ServingEngine, TickClock
from repro.serve.traffic import make_traffic, scenario_preset

N_DEV = 8


@pytest.fixture(scope="module")
def auto():
    return ServeAutoscaler(N_DEV, n_slots=4)


def test_device_loss_reprices_with_lemma1_and_shrinks_slots(auto):
    base_epoch = auto._base_epoch_s
    d = auto.on_device_loss(2, now=1.5)
    assert isinstance(d, ReplanDecision) and d.reason == "device_loss"
    assert (d.from_devices, d.to_devices) == (8, 6)
    assert d.at_s == 1.5
    # Lemma-1 allocation on the survivors: one entry per pipeline stage,
    # each within the 6-core ring
    assert d.lemma1_cores and all(1 <= c <= 6 for c in d.lemma1_cores)
    # fewer cores => slower epoch => fewer admitted slots
    assert d.epoch_s > base_epoch
    assert d.to_slots <= d.from_slots
    assert d.to_slots == max(1, round(4 * base_epoch / d.epoch_s))
    assert auto.n_devices == 6 and auto.n_slots == d.to_slots
    assert auto.events[-1] is d


def test_slo_violation_grows_toward_capacity_then_saturates(auto):
    start = auto.n_slots
    d = auto.on_slo_violation(now=2.0, p99_ttft_s=1.0)
    assert d is not None and d.reason == "slo_violation"
    assert d.to_slots == min(auto.max_slots, start + max(1, start // 2))
    assert d.lemma1_cores is not None     # re-derived for current membership
    while (d := auto.on_slo_violation(3.0, 1.0)) is not None:
        assert d.to_slots <= auto.max_slots
    assert auto.n_slots == auto.max_slots  # saturated: further calls refuse
    assert auto.on_slo_violation(4.0, 1.0) is None


def test_slot_floor_survives_heavy_loss():
    a = ServeAutoscaler(N_DEV, n_slots=2, min_slots=1)
    d = a.on_device_loss(N_DEV - 1, now=0.0)   # down to a single core
    assert d.to_devices == 1
    assert d.to_slots >= 1
    assert d.to_dict()["lemma1_cores"] == list(d.lemma1_cores)


def test_device_loss_mid_decode_streams_match_no_fault_run():
    cfg = smoke_config("qwen3-14b")
    sc = scenario_preset("device-loss-mid-decode", n_requests=6,
                         prompt_buckets=(8,), gen_buckets=(4, 8),
                         device_loss=(2, 2))
    trace = make_traffic(sc, seed=0)

    def serve(run_sc):
        runner = JaxModelRunner(cfg, n_slots=3, max_len=sc.max_len)
        auto = ServeAutoscaler(runner.n_devices, 3)
        engine = ServingEngine(runner, n_slots=3, clock=TickClock(0.01),
                               autoscaler=auto)
        return engine.run(trace, run_sc), runner

    faulted, runner = serve(sc)
    clean, _ = serve(sc.replace(device_loss=None))

    # the fault really happened and forced restarts + a rebuild
    assert [r.reason for r in faulted.replans] == ["device_loss"]
    assert faulted.replans[0].to_devices == 6
    assert runner.n_devices == 6
    assert faulted.slo.n_restarts >= 1

    # ...and cost zero tokens: every stream matches the no-fault run
    assert not clean.replans and clean.slo.n_restarts == 0
    assert set(faulted.streams) == set(trace.rids)
    assert faulted.streams == clean.streams
    for ev in trace.events:
        assert len(faulted.streams[ev.rid]) == ev.gen_len


def test_rebuild_repartitions_params_on_survivors():
    cfg = smoke_config("qwen3-14b")
    runner = JaxModelRunner(cfg, n_slots=2, max_len=16)
    assert runner.n_devices == N_DEV
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    first_before = runner.prefill(0, prompt)
    runner.rebuild(n_devices=6, n_slots=3)
    assert runner.n_devices == 6 and runner.n_slots == 3
    # params re-placed from the host-canonical copy: same math
    assert runner.prefill(0, prompt) == first_before
    with pytest.raises(ValueError, match="at least one device"):
        runner.rebuild(n_devices=0)
