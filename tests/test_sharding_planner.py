"""Sharding resolution + the ONoC->TPU planner."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.core.planner import (
    TPUTarget,
    feasible_degrees,
    plan_fcnn,
    plan_gemm_period,
)
from repro.parallel.sharding import (
    AxisRules,
    DEFAULT_RULES,
    resolve_spec,
    shape_aware_shardings,
)


def _mesh():
    n = len(jax.devices())
    return Mesh(np.array(jax.devices()).reshape(n, 1), ("data", "model"))


@given(st.integers(1, 4096), st.sampled_from(["vocab", "heads", "mlp"]))
def test_resolve_spec_always_divides(dim, axis):
    mesh = _mesh()
    spec = resolve_spec((dim,), (axis,), mesh, DEFAULT_RULES)
    ways = 1
    entry = spec[0]
    if entry is not None:
        names = (entry,) if isinstance(entry, str) else entry
        for a in names:
            ways *= mesh.shape[a]
    assert dim % ways == 0


def test_resolve_spec_demotes_prefix():
    devs = np.array(jax.devices()[:1])
    mesh = Mesh(devs.reshape(1, 1), ("data", "model"))
    rules = AxisRules().override(activation_batch=("pod", "data"))
    # "pod" missing on this mesh: silently dropped
    spec = resolve_spec((4, 4), ("activation_batch", None), mesh, rules)
    assert spec == P(("data",), None)


def test_shape_aware_shardings_structure_check():
    mesh = _mesh()
    spec = {"a": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    with pytest.raises(ValueError):
        shape_aware_shardings(spec, {"a": (None,), "b": (None,)}, mesh)


def test_feasible_degrees():
    feas = feasible_degrees({"data": 16, "model": 16})
    assert feas[1] == ()
    assert feas[16] in (("model",), ("data",))
    assert feas[256] == ("model", "data")
    feas3 = feasible_degrees({"pod": 2, "data": 16, "model": 16})
    assert 512 in feas3


def test_feasible_degrees_noncontiguous_products():
    """Regression: degrees from NON-contiguous axis subsets (model×pod)
    must be enumerated — the old prefix-run enumeration missed them and
    plans silently snapped to a worse degree."""
    feas = feasible_degrees({"model": 2, "data": 3, "pod": 2})
    assert set(feas) == {1, 2, 3, 4, 6, 12}
    assert feas[4] == ("model", "pod")       # the previously missing one
    assert feas[2] == ("model",)             # fewer axes win ties
    assert feas[6] == ("model", "data")
    assert feas[12] == ("model", "data", "pod")
    # snapping a target of 4 now lands exactly on 4 (it used to go to 3)
    from repro.core.planner import _snap_degree
    assert _snap_degree(4, feas) == 4


def test_plan_fcnn_snaps_into_enlarged_feasible_set():
    """plan_fcnn on a non-contiguous-product mesh only emits feasible,
    divisibility-respecting degrees."""
    w = FCNNWorkload([784, 1500, 784, 1000, 500, 10], batch_size=8)
    cfg = ONoCConfig(lambda_max=64)
    mesh = {"model": 2, "data": 3, "pod": 2}
    plan = plan_fcnn(w, cfg, mesh)
    feas = feasible_degrees(mesh)
    for p in plan.periods:
        assert p.degree in feas
        assert w.n(p.period) % p.degree == 0
        assert p.axes == feas[p.degree]


def test_plan_fcnn_degrees_feasible_and_capped():
    w = FCNNWorkload([784, 1500, 784, 1000, 500, 10], batch_size=8)
    cfg = ONoCConfig(lambda_max=64)
    plan = plan_fcnn(w, cfg, {"data": 16, "model": 16})
    feas = set(feasible_degrees({"data": 16, "model": 16}))
    for p in plan.periods:
        assert p.degree in feas
        assert p.degree <= w.n(p.period)
        assert p.degree <= 256
    # the output layer (10 neurons) can never exceed 10 ways
    assert plan.periods[-1].degree <= 10


def test_plan_gemm_period_tradeoff():
    """Small GEMMs plan low degrees, huge GEMMs saturate — the paper's
    compute/communication trade-off on TPU terms."""
    mesh = {"data": 16, "model": 16}
    small, _, _ = plan_gemm_period(
        flops=1e6, act_bytes_in=1e6, act_bytes_out=1e6, mesh_axes=mesh)
    huge, _, _ = plan_gemm_period(
        flops=1e15, act_bytes_in=1e6, act_bytes_out=1e6, mesh_axes=mesh)
    assert small <= huge
    assert huge == 256


def test_plan_gemm_costs_monotone_compute():
    mesh = {"data": 4, "model": 4}
    _, _, costs = plan_gemm_period(
        flops=1e12, act_bytes_in=0.0, act_bytes_out=0.0, mesh_axes=mesh)
    # with zero comm, cost strictly decreases with degree
    degs = sorted(costs)
    vals = [costs[d] for d in degs]
    assert all(a > b for a, b in zip(vals, vals[1:]))
