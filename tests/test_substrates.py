"""Checkpointing, optimizers, gradient sync, data pipeline, runtime."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.data import Batcher, fcnn_classification_dataset, token_stream
from repro.optim import adam, adamw, clip_by_global_norm, momentum, sgd
from repro.parallel import gradsync
from repro.runtime import StragglerMonitor, TrainingSupervisor


# ------------------------------------------------------------- checkpoint

def _state(v=0.0):
    return {"params": {"w": jnp.full((4, 4), v), "b": jnp.zeros((4,))},
            "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    st = _state(3.0)
    ck.save(10, st)
    assert latest_step(str(tmp_path)) == 10
    restored = ck.restore(10, jax.eval_shape(lambda: st))
    np.testing.assert_array_equal(restored["params"]["w"], st["params"]["w"])
    assert int(restored["step"]) == 3


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(float(s)), blocking=(s % 2 == 0))
    ck.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _state(1.0))
    assert not [d for d in os.listdir(tmp_path) if d.startswith("tmp.")]
    meta = ck.meta(5)
    assert meta["step"] == 5


# ------------------------------------------------------------- optimizers

@pytest.mark.parametrize("opt_fn", [
    lambda: sgd(0.1),
    lambda: momentum(0.05, 0.9),
    lambda: adam(0.1),
    lambda: adamw(0.1, weight_decay=0.0),
])
def test_optimizers_minimize_quadratic(opt_fn):
    opt = opt_fn()
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    state = opt.init(params)
    for step in range(300):
        grads = {"x": 2 * (params["x"] - target)}
        params, state = opt.update(grads, state, params, step)
    np.testing.assert_allclose(params["x"], target, atol=1e-2)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    total = jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------- gradsync

def test_accumulate_grads_matches_full_batch():
    w = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]])}
    x = jnp.arange(8.0).reshape(4, 2)

    def loss(params, batch):
        return jnp.mean((batch["x"] @ params["w"]) ** 2)

    full_loss, full_grads = jax.value_and_grad(loss)(w, {"x": x})
    mb = {"x": x.reshape(2, 2, 2)}
    acc_loss, acc_grads = gradsync.accumulate_grads(loss, w, mb)
    np.testing.assert_allclose(acc_loss, full_loss, rtol=1e-6)
    # mean over microbatches == full-batch mean for equal-sized microbatches
    np.testing.assert_allclose(acc_grads["w"], full_grads["w"], rtol=1e-6)


def test_accumulate_grads_keeps_param_dtype():
    """bf16 params accumulate in bf16 — no silent fp32 upcast (ISSUE 6)."""
    w = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.bfloat16),
         "b": jnp.zeros((2,), jnp.float32)}
    mb = {"x": jnp.arange(8.0, dtype=jnp.bfloat16).reshape(2, 2, 2)}

    def loss(params, batch):
        h = batch["x"].astype(jnp.float32) @ params["w"].astype(jnp.float32)
        return jnp.mean((h + params["b"]) ** 2)

    _, grads = gradsync.accumulate_grads(loss, w, mb)
    assert grads["w"].dtype == jnp.bfloat16
    assert grads["b"].dtype == jnp.float32


def test_accumulate_grads_acc_dtype_override():
    """acc_dtype=fp32 accumulates (and returns) bf16 grads at fp32."""
    w = {"w": jnp.asarray([[1.0, 2.0], [3.0, 4.0]], jnp.bfloat16)}
    x = jnp.arange(32.0, dtype=jnp.bfloat16).reshape(16, 2)

    def loss(params, batch):
        h = batch["x"].astype(jnp.float32) @ params["w"].astype(jnp.float32)
        return jnp.mean(h ** 2)

    mb = {"x": x.reshape(8, 2, 2)}
    _, acc32 = gradsync.accumulate_grads(loss, w, mb, acc_dtype=jnp.float32)
    assert acc32["w"].dtype == jnp.float32
    # the fp32 accumulator matches the full-batch fp32 grad more closely
    # than 8 rounds of bf16 rounding possibly could
    full = jax.grad(loss)({"w": w["w"].astype(jnp.float32)},
                          {"x": x})["w"]
    np.testing.assert_allclose(acc32["w"], full, rtol=1e-2)


def test_int8_error_feedback_compensates():
    rng = np.random.default_rng(0)
    g_true = {"w": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    res = gradsync.init_residual(g_true)
    applied = jnp.zeros((64,))
    for _ in range(50):
        deq, res = gradsync.compress_grads_ef(g_true, res)
        applied = applied + deq["w"]
    # over many steps the error feedback makes the mean applied grad
    # converge to the true grad
    np.testing.assert_allclose(applied / 50, g_true["w"], atol=2e-2)


def test_quantize_roundtrip_bound():
    g = jnp.linspace(-3, 3, 256)
    q, s = gradsync.quantize_int8(g)
    err = jnp.max(jnp.abs(gradsync.dequantize_int8(q, s) - g))
    assert float(err) <= float(s) * 0.5 + 1e-6


# -------------------------------------------------------------------- data

def test_batcher_deterministic_and_resumable():
    x, y = fcnn_classification_dataset(64, input_dim=8)
    b1 = Batcher({"x": x, "y": y}, batch_size=8)
    batches = [next(b1) for _ in range(3)]
    state = b1.state()
    nxt = next(b1)

    b2 = Batcher({"x": x, "y": y}, batch_size=8)
    b2.restore(state)
    nxt2 = next(b2)
    np.testing.assert_array_equal(nxt["x"], nxt2["x"])
    # first batches reproducible from scratch
    b3 = Batcher({"x": x, "y": y}, batch_size=8)
    np.testing.assert_array_equal(batches[0]["x"], next(b3)["x"])


def test_token_stream_learnable_structure():
    s = token_stream(10000, vocab=50, seed=0)
    follows = np.mean(s[1:] == (s[:-1] * 7 + 3) % 50)
    # the vectorized injection reads pre-update predecessors, so chained
    # follows dilute the realized rate below the nominal 0.5
    assert follows > 0.2        # injected bigram structure is present
    assert s.min() >= 0 and s.max() < 50


# ----------------------------------------------------------------- runtime

def test_supervisor_restarts_from_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path))
    sup = TrainingSupervisor(ck, checkpoint_every=2, max_retries=1,
                             backoff_s=0.0)
    x, y = fcnn_classification_dataset(32, input_dim=4)
    batches = Batcher({"x": x, "y": y}, batch_size=4)

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 6:       # persistent failure at one step
            raise RuntimeError("injected fault")
        return {"v": state["v"] + 1.0}, {"loss": 1.0}

    state, history = sup.run({"v": jnp.zeros(())}, step_fn, batches, 8)
    assert len(history) == 8
    # checkpoint+restart happened (extra calls for retry + replay)
    assert calls["n"] > 8


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(deadline_factor=2.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 1.0) is True
    assert 10 in mon.straggler_steps
    assert mon.observe(11, 0.1) is False
