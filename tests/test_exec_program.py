"""Schedule compiler: program structure, serialization, and the cost
contract with core.simulator.simulate_epoch (ISSUE 6 acceptance: same
2l-2 transition schedule, identical cost annotations, all strategies)."""

import math

import pytest

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.allocation import MappingStrategy
from repro.core.planner import plan_fcnn, ring_mesh_axes
from repro.core.simulator import ENoCBackend, ONoCBackend, simulate_epoch
from repro.exec.program import (
    Instruction,
    Opcode,
    PeriodProgram,
    compile_fcnn_program,
    compile_program,
    snap_to_ring_degree,
)

N_DEV = 8
STRATEGIES = list(MappingStrategy)


def _compile(nn="NN1", batch=8, strategy="orrm", backend=None, n_dev=N_DEV):
    w = workload(nn, batch_size=batch)
    cfg = onoc_config(lambda_max=64)
    prog = compile_fcnn_program(w, cfg, n_dev, strategy, backend=backend)
    return w, cfg, prog


# ----------------------------------------------------------------- structure

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("nn", ["NN1", "NN2"])
def test_program_structure(nn, strategy):
    w, cfg, prog = _compile(nn, strategy=strategy)
    l = w.l
    runs = prog.runs()
    assert len(runs) == 2 * l
    assert [r.period for r in runs] == list(range(1, 2 * l + 1))
    sends = prog.sends()
    recvs = [i for i in prog.instructions if i.opcode is Opcode.RECV]
    assert len(sends) == 2 * l - 2 and len(recvs) == 2 * l - 2
    # the simulator's schedule: periods {1..2l-1} minus the turnaround l
    assert prog.transition_schedule() == [
        i for i in range(1, 2 * l) if i != l]

    for r in runs:
        n_i = prog.layer_sizes[r.layer]
        assert r.degree == len(r.devices) > 0
        assert n_i % r.degree == 0 and N_DEV % r.degree == 0
        assert r.chunk_width == n_i // r.degree
        assert all(0 <= d < N_DEV for d in r.devices)
        assert r.cost_s > 0
    # Eq. 11 data locality: BP period windows mirror FP
    by_period = {r.period: r for r in runs}
    for i in range(1, l + 1):
        assert by_period[i].devices == by_period[2 * l - i + 1].devices


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_free_matches_window_diffs(strategy):
    w, cfg, prog = _compile("NN1", strategy=strategy)
    runs = {r.period: r for r in prog.runs()}
    frees = {f.period: f for f in prog.frees("window")}
    for i in range(1, 2 * w.l):
        released = sorted(set(runs[i].devices) - set(runs[i + 1].devices))
        if released:
            assert list(frees[i].devices) == released
        else:
            assert i not in frees
    # epoch end: the whole final window is released
    assert sorted(frees[2 * w.l].devices) == sorted(runs[2 * w.l].devices)


def test_snap_to_ring_degree():
    # divisors of both 8 and 500: {1, 2, 4}
    assert snap_to_ring_degree(8, 8, 500) == 4
    assert snap_to_ring_degree(1, 8, 500) == 1
    assert snap_to_ring_degree(3, 8, 500) == 4    # log-tie prefers larger
    assert snap_to_ring_degree(1000, 8, 1000) == 8
    assert snap_to_ring_degree(5, 7, 10) == 1     # 7 shares no divisor >1


def test_compile_resnap_from_foreign_mesh():
    """A plan made for a bigger mesh compiles onto an 8-device ring."""
    w = workload("NN1", batch_size=8)
    cfg = onoc_config()
    plan = plan_fcnn(w, cfg, {"data": 16, "model": 16}, strategy="rrm")
    prog = compile_program(plan, w, cfg, N_DEV)
    for r in prog.runs():
        assert N_DEV % r.degree == 0
        assert prog.layer_sizes[r.layer] % r.degree == 0


def test_ring_mesh_axes_cover_divisors():
    from repro.core.planner import feasible_degrees
    for n in (1, 4, 8, 12, 60):
        feas = feasible_degrees(ring_mesh_axes(n))
        divisors = {d for d in range(1, n + 1) if n % d == 0}
        assert divisors <= set(feas)
        assert math.prod(ring_mesh_axes(n).values()) == n


# -------------------------------------------------------------- cost contract

@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("backend_cls", [ONoCBackend, ENoCBackend])
@pytest.mark.parametrize("nn", ["NN1", "NN2"])
def test_cost_annotation_matches_simulate_epoch(nn, strategy, backend_cls):
    """The executable contract: program cost annotations == the simulator's
    EpochTrace, transition by transition, for every mapping strategy on
    both interconnect backends."""
    backend = backend_cls()
    w, cfg, prog = _compile(nn, strategy=strategy, backend=backend)
    plan = plan_fcnn(w, cfg, ring_mesh_axes(N_DEV), strategy=strategy)
    trace = simulate_epoch(w, cfg, mapping=plan.mapping, backend=backend)

    assert prog.compute_s == trace.compute_s
    assert prog.comm_s == trace.comm_s
    sends = prog.sends()
    assert len(sends) == len(trace.transitions) == 2 * w.l - 2
    for ins, tr in zip(sends, trace.transitions):
        assert ins.period == tr.period
        assert ins.cost_s == tr.comm_s
        assert ins.bytes_per_sender == tr.bytes_per_sender
        assert ins.slots == tr.slots
        assert ins.hop_bytes == tr.hop_bytes
    # per-period compute agrees too
    for r, f in zip(prog.runs(), trace.per_period_compute_s):
        assert r.cost_s == f


def test_onoc_period1_send_is_free_but_recorded():
    w, cfg, prog = _compile("NN1", strategy="fm", backend=ONoCBackend())
    first = prog.sends()[0]
    assert first.period == 1
    assert first.cost_s == 0.0
    assert first.bytes_per_sender > 0


def test_enoc_period1_send_is_paid():
    w, cfg, prog = _compile("NN1", strategy="fm", backend=ENoCBackend())
    first = prog.sends()[0]
    assert first.period == 1
    assert first.cost_s > 0.0


# -------------------------------------------------------------- serialization

@pytest.mark.parametrize("strategy", STRATEGIES)
def test_json_round_trip(strategy):
    _, _, prog = _compile("NN2", strategy=strategy)
    js = prog.to_json()
    back = PeriodProgram.from_json(js)
    assert back == prog
    assert back.to_json() == js


def test_json_version_guard():
    _, _, prog = _compile()
    bad = prog.to_json().replace('"version": 2', '"version": 99', 1)
    with pytest.raises(ValueError):
        PeriodProgram.from_json(bad)


def test_instruction_constructors():
    run = Instruction.RUN(period=1, layer=1, phase="fp",
                          activation="sigmoid", onoc_cores=100, degree=4,
                          chunk_width=250, window=(0, 1, 2, 3), cost_s=1.0)
    assert run.opcode is Opcode.RUN and run.devices == (0, 1, 2, 3)
    send = Instruction.SEND(period=1, senders=(0,), cost_s=0.5,
                            bytes_per_sender=64.0, slots=2, hop_bytes=0.0)
    assert send.opcode is Opcode.SEND and send.cost_s == 0.5
    assert Instruction.RECV(period=1, receivers=(1,)).cost_s == 0.0
    assert Instruction.FREE(period=1, released=(0,)).devices == (0,)
