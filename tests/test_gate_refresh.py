"""Baseline refresh flow (ISSUE 9 satellite: benchmarks/gate.py
--refresh): snapshot + history append, the min-reducer merge, and the
guarantee that ``compare`` never reads the history trail."""

import statistics

from benchmarks.gate import (
    baseline_snapshot,
    compare,
    merge_ratio_stats,
    refresh_baseline,
)


def _report(speedup=2.0, check="check,table7,plateau -> PASS"):
    return {
        "checks": [check],
        "benchmarks": {
            "softmax_xent_microbench": {
                "rows": [{"case": "b64", "fwd_speedup": speedup,
                          "fwdbwd_speedup": speedup + 0.5}],
            },
        },
    }


def test_baseline_snapshot_summarizes_checks_and_ratios():
    snap = baseline_snapshot(_report(speedup=2.0))
    assert snap["checks_pass"] == 1
    assert snap["checks_fail"] == 0
    assert snap["n_benchmarks"] == 1
    assert snap["ratios"] == {
        "softmax_xent_microbench/b64/fwd_speedup": 2.0,
        "softmax_xent_microbench/b64/fwdbwd_speedup": 2.5,
    }


def test_refresh_appends_history_and_keeps_prior_trail():
    base = _report(speedup=2.0)
    cur = _report(speedup=1.5)
    refreshed = refresh_baseline(base, cur, stamp="2026-08-08T00:00:00Z")
    assert refreshed["benchmarks"] == cur["benchmarks"]  # new numbers win
    (entry,) = refreshed["history"]
    assert entry["refreshed"] == "2026-08-08T00:00:00Z"
    assert entry["previous"] == baseline_snapshot(base)
    # a second refresh extends, never rewrites, the trail
    again = refresh_baseline(refreshed, _report(speedup=1.8), stamp="later")
    assert [e["refreshed"] for e in again["history"]] == [
        "2026-08-08T00:00:00Z", "later"]
    assert again["history"][1]["previous"] == baseline_snapshot(refreshed)


def test_refresh_merge_uses_min_not_median():
    """Refresh snapshots the per-case minimum across repeats — the
    conservative floor — while gating keeps the median."""
    reports = [_report(speedup=s) for s in (2.0, 1.2, 3.0)]
    floor = merge_ratio_stats([dict(r, benchmarks={
        k: {"rows": [dict(row) for row in v["rows"]]}
        for k, v in r["benchmarks"].items()}) for r in reports], min)
    row = floor["benchmarks"]["softmax_xent_microbench"]["rows"][0]
    assert row["fwd_speedup"] == 1.2
    med = merge_ratio_stats(reports, statistics.median)
    row = med["benchmarks"]["softmax_xent_microbench"]["rows"][0]
    assert row["fwd_speedup"] == 2.0


def test_compare_ignores_history():
    base = refresh_baseline(_report(2.0), _report(2.0), stamp="x")
    assert compare(base, _report(2.0), slowdown=0.20) == []
    # regressions are still caught with history present
    failures = compare(base, _report(1.0), slowdown=0.20)
    assert any("fwd_speedup" in f for f in failures)


def test_refreshed_baseline_relaxes_the_gate():
    """The point of --refresh: after accepting a slower baseline, the
    same slower report passes the gate."""
    old = _report(speedup=2.0)
    slower = _report(speedup=1.5)
    assert compare(old, slower, slowdown=0.20)          # gated out before
    new_base = refresh_baseline(old, slower, stamp="x")
    assert compare(new_base, slower, slowdown=0.20) == []
