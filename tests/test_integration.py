"""End-to-end integration: FCNN training with the paper's plan actually
learns; the LM train loop with supervisor+checkpoint converges; elastic
re-planning re-derives allocations."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.configs.base import ShapeSpec
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.data import Batcher, fcnn_classification_dataset
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models import fcnn
from repro.models.api import get_model
from repro.optim import adam
from repro.runtime.elastic import ElasticPlanner


def test_fcnn_training_learns():
    """Train a small FCNN on the synthetic classification set; accuracy
    must beat chance by a wide margin (the paper's workload, miniature)."""
    key = jax.random.PRNGKey(0)
    sizes = [32, 64, 32, 10]
    params = fcnn.init(key, sizes)
    x, y = fcnn_classification_dataset(512, input_dim=32, seed=3)
    opt = adam(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch, i):
        loss, grads = jax.value_and_grad(fcnn.loss_fn)(params, batch)
        params, state = opt.update(grads, state, params, i)
        return params, state, loss

    batcher = Batcher({"x": x, "y": y}, batch_size=64)
    losses = []
    for i in range(400):
        batch = next(batcher)
        params, state, loss = step(params, state, batch, i)
        losses.append(float(loss))
    acc = float(fcnn.accuracy(params, jnp.asarray(x), jnp.asarray(y)))
    assert losses[-1] < losses[0] * 0.5
    assert acc > 0.6


def test_lm_train_step_decreases_loss():
    cfg = smoke_config("granite-3-2b")
    model = get_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 32, 4, "train")
    settings = steps_lib.TrainSettings(learning_rate=1e-3)
    with mesh:
        step, st_sh, _, _ = steps_lib.build_train_step(model, mesh, shape,
                                                       settings)
        state = jax.device_put(
            steps_lib.init_train_state(model, settings, jax.random.PRNGKey(0)),
            st_sh)
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                 cfg.vocab_size)
        batch = {"tokens": tok, "labels": tok}
        first = None
        for _ in range(10):
            state, metrics = step(state, batch)
            if first is None:
                first = float(metrics["loss"])
        assert float(metrics["loss"]) < first


def test_int8_compression_still_learns():
    cfg = smoke_config("granite-3-2b")
    model = get_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 32, 4, "train")
    settings = steps_lib.TrainSettings(learning_rate=1e-3,
                                       grad_compression="int8")
    with mesh:
        step, st_sh, _, _ = steps_lib.build_train_step(model, mesh, shape,
                                                       settings)
        state = jax.device_put(
            steps_lib.init_train_state(model, settings, jax.random.PRNGKey(0)),
            st_sh)
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                 cfg.vocab_size)
        batch = {"tokens": tok, "labels": tok}
        first = None
        for _ in range(10):
            state, metrics = step(state, batch)
            if first is None:
                first = float(metrics["loss"])
        assert float(metrics["loss"]) < first


def test_microbatched_step_matches_shapes():
    cfg = smoke_config("qwen3-14b")
    model = get_model(cfg)
    mesh = make_host_mesh()
    shape = ShapeSpec("t", 16, 8, "train")
    settings = steps_lib.TrainSettings(microbatches=2)
    with mesh:
        step, st_sh, _, _ = steps_lib.build_train_step(model, mesh, shape,
                                                       settings)
        state = jax.device_put(
            steps_lib.init_train_state(model, settings, jax.random.PRNGKey(0)),
            st_sh)
        tok = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                 cfg.vocab_size)
        state, metrics = step(state, {"tokens": tok, "labels": tok})
        assert jnp.isfinite(metrics["loss"])
        assert int(state["step"]) == 1


def test_elastic_replanning():
    """Membership change -> the ONoC model re-derives the allocation."""
    w = FCNNWorkload([784, 1000, 500, 10], batch_size=8)
    planner = ElasticPlanner(w, ONoCConfig(lambda_max=8))
    cfg_full, cores_full, _ = planner.plan_for(1000)
    cfg_degraded, cores_degraded, mapping = planner.plan_for(700)
    assert max(cores_degraded) <= 700
    assert cores_degraded != cores_full
    assert mapping.m == 700
    # shrink further: still valid
    _, cores_tiny, _ = planner.plan_for(16)
    assert max(cores_tiny) <= 16
