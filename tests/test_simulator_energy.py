"""Simulator + energy model behaviour (paper Section 5 claims, in
relative/structural form)."""

import pytest
from hypothesis import given, strategies as st

from repro.core import (
    ENoCBackend,
    FCNNWorkload,
    MappingStrategy,
    ONoCConfig,
    enoc_energy,
    fgp_cores,
    fnp_cores,
    map_cores,
    onoc_energy,
    optimal_cores,
    simulate_epoch,
)
from repro.core.analyses import analyze_mapping
from repro.core.onoc_model import epoch_time
from repro.core.simulator import ENoCConfig

sizes_st = st.lists(st.integers(16, 500), min_size=2, max_size=5).map(
    lambda mid: [80] + mid + [10])


@given(sizes_st, st.sampled_from([8, 64]))
def test_onoc_time_strategy_invariant(sizes, lam):
    """Paper §5.4: FM/RRM/ORRM are equivalent on ONoC (distance-free)."""
    w = FCNNWorkload(sizes, batch_size=4)
    cfg = ONoCConfig(lambda_max=lam)
    ts = []
    for s in MappingStrategy:
        tr = simulate_epoch(w, cfg, strategy=s)
        ts.append(tr.total_s)
    assert max(ts) - min(ts) < 1e-12


@given(sizes_st)
def test_simulator_matches_analytic_model(sizes):
    """The ONoC simulator must agree with Eq. (7) (same model, two paths)."""
    w = FCNNWorkload(sizes, batch_size=2)
    cfg = ONoCConfig(lambda_max=8)
    cores = optimal_cores(w, cfg)
    t_analytic, _ = epoch_time(w, cfg, cores)
    tr = simulate_epoch(w, cfg, strategy="fm", cores_per_period=cores)
    assert tr.total_s == pytest.approx(t_analytic, rel=1e-9)


@given(sizes_st)
def test_optimal_no_worse_than_baselines(sizes):
    """Table 8's direction: OPT <= FNP and OPT <= FGP in epoch time."""
    w = FCNNWorkload(sizes, batch_size=8)
    cfg = ONoCConfig(lambda_max=8)
    t = {}
    for name, cores in (
        ("opt", optimal_cores(w, cfg, refine_plateau=True)),
        ("fgp", fgp_cores(w, cfg)),
        ("fnp", fnp_cores(w, cfg)),
    ):
        t[name] = simulate_epoch(w, cfg, strategy="fm",
                                 cores_per_period=cores).total_s
    assert t["opt"] <= t["fgp"] * (1 + 1e-9)
    assert t["opt"] <= t["fnp"] * (1 + 1e-9)


def test_onoc_beats_enoc_at_scale():
    """Fig. 10a: ONoC total time below ENoC, gap growing with cores."""
    w = FCNNWorkload([784, 1500, 784, 1000, 500, 10], batch_size=64)
    cfg = ONoCConfig(lambda_max=64)
    gaps = []
    for fixed in (40, 150, 350):
        cores = fnp_cores(w, cfg, fixed)
        mp = map_cores(w, cfg, "fm", cores)
        t_o = simulate_epoch(w, cfg, mapping=mp).total_s
        t_e = simulate_epoch(w, cfg, mapping=mp,
                             backend=ENoCBackend()).total_s
        assert t_o < t_e
        gaps.append((t_e - t_o) / t_e)
    assert gaps[0] < gaps[-1]


def test_enoc_energy_grows_with_hops():
    """Fig. 10b's driver: ENoC dynamic energy scales with bytes×hops."""
    w = FCNNWorkload([784, 1000, 500, 10], batch_size=8)
    cfg = ONoCConfig(lambda_max=64)
    es = []
    for fixed in (40, 350):
        cores = fnp_cores(w, cfg, fixed)
        mp = map_cores(w, cfg, "fm", cores)
        tr = simulate_epoch(w, cfg, mapping=mp, backend=ENoCBackend())
        rep = analyze_mapping(w, mp)
        es.append(enoc_energy(tr, mp, rep.state_transitions).dynamic_j)
    assert es[1] > es[0]


@given(sizes_st, st.sampled_from([40, 150]),
       st.sampled_from(list(MappingStrategy)))
def test_enoc_vectorized_matches_loop(sizes, fixed, strategy):
    """The numpy link-load accumulation must be bit-identical to the
    original per-pair Python loop (comm_s AND hop_bytes)."""
    w = FCNNWorkload(sizes, batch_size=8)
    cfg = ONoCConfig(lambda_max=64)
    cores = fnp_cores(w, cfg, fixed)
    mp = map_cores(w, cfg, strategy, cores)
    be = ENoCBackend()
    for i in range(1, 2 * w.l):
        if i in (w.l, 2 * w.l):
            continue
        fast = be.transition_time(w, cfg, i, mp)
        ref = be.transition_time_reference(w, cfg, i, mp)
        assert fast.comm_s == ref.comm_s
        assert fast.hop_bytes == ref.hop_bytes
        assert fast.senders == ref.senders
        assert fast.receivers == ref.receivers


def test_enoc_vectorized_single_core_window():
    """Degenerate windows (1 sender == 1 receiver) produce zero traffic."""
    w = FCNNWorkload([32, 16, 10], batch_size=2)
    cfg = ONoCConfig(lambda_max=8)
    mp = map_cores(w, cfg, "fm", [1, 1])
    be = ENoCBackend()
    tr = be.transition_time(w, cfg, 1, mp)
    ref = be.transition_time_reference(w, cfg, 1, mp)
    assert tr.comm_s == ref.comm_s
    assert tr.hop_bytes == ref.hop_bytes


def test_transition_schedule_pinned():
    """Eq. (6)'s transition schedule: exactly 2l−2 transitions, at periods
    {1..2l−1} \\ {l}; the period-1 hand-off is zero-charged ONLY on ONoC
    (traffic still recorded), while ENoC pays for it."""
    w = FCNNWorkload([80, 40, 20, 10], batch_size=4)   # l = 3
    cfg = ONoCConfig(lambda_max=8)
    expected = [i for i in range(1, 2 * w.l) if i != w.l]

    tr_o = simulate_epoch(w, cfg, strategy="fm")
    assert len(tr_o.transitions) == 2 * w.l - 2
    assert [t.period for t in tr_o.transitions] == expected
    first = tr_o.transitions[0]
    assert first.period == 1 and first.comm_s == 0.0
    assert first.bytes_per_sender > 0          # traffic recorded anyway
    assert all(t.comm_s > 0 for t in tr_o.transitions[1:])

    tr_e = simulate_epoch(w, cfg, strategy="fm", backend=ENoCBackend())
    assert [t.period for t in tr_e.transitions] == expected
    assert tr_e.transitions[0].comm_s > 0      # nothing is free on ENoC


def test_enoc_channels_scale_drain():
    """The router channel count divides the per-link drain time, in both
    the vectorized model and the per-pair oracle."""
    w = FCNNWorkload([784, 1000, 500, 10], batch_size=8)
    cfg = ONoCConfig(lambda_max=64)
    mp = map_cores(w, cfg, "fm", fnp_cores(w, cfg, 150))
    be1, be2, be4 = (ENoCBackend(ENoCConfig(channels=c)) for c in (1, 2, 4))
    for i in range(1, 2 * w.l):
        if i == w.l:
            continue
        t1, t2, t4 = (be.transition_time(w, cfg, i, mp)
                      for be in (be1, be2, be4))
        for be, t in ((be1, t1), (be2, t2), (be4, t4)):
            ref = be.transition_time_reference(w, cfg, i, mp)
            assert t.comm_s == ref.comm_s and t.hop_bytes == ref.hop_bytes
        # comm = drain/channels + latency: solve (drain, latency) from the
        # 1- and 4-channel runs, then the 2-channel run must land on the
        # same line — i.e. the channel count divides exactly the drain term
        drain = (t1.comm_s - t4.comm_s) * 4.0 / 3.0
        latency = t1.comm_s - drain
        assert drain > 0 and latency >= 0
        assert t2.comm_s == pytest.approx(drain / 2.0 + latency)
        # hop_bytes is a traffic volume, independent of channels
        assert t1.hop_bytes == t2.hop_bytes == t4.hop_bytes


def test_energy_breakdown_positive():
    w = FCNNWorkload([784, 1000, 500, 10], batch_size=8)
    cfg = ONoCConfig(lambda_max=64)
    mp = map_cores(w, cfg, "orrm")
    rep = analyze_mapping(w, mp)
    tr_o = simulate_epoch(w, cfg, mapping=mp)
    e = onoc_energy(tr_o, mp, rep.state_transitions)
    assert e.static_j > 0 and e.dynamic_j > 0 and e.compute_j > 0
    assert e.total_j == pytest.approx(e.static_j + e.dynamic_j + e.compute_j)
