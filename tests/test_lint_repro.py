"""Repo lint rules (ISSUE 9: tools/lint_repro.py) — unit tests on
``lint_source`` plus the repo-wide pass that backs ``make lint``."""

import os
import sys
import textwrap

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO_ROOT, "tools"))

from lint_repro import (  # noqa: E402
    check_kernel_coverage,
    lint_source,
    main,
)


def _lint(src):
    return lint_source(textwrap.dedent(src), "x.py")


# -------------------------------------------------------- deprecated-call

def test_flags_deprecated_shim_call():
    (v,) = _lint("""
        from repro.exec.runtime import build_train_step
        step, ex = build_train_step(prog, mesh, opt)
        """)
    assert v.rule == "deprecated-call"
    assert "build_train_step" in v.message
    assert v.line == 3


def test_flags_aliased_deprecated_call():
    (v,) = _lint("""
        import repro.exec as rexec
        rexec.build_train_step(prog, mesh, opt)
        """)
    assert v.rule == "deprecated-call"
    (v,) = _lint("""
        from repro.launch import steps as st
        st.build_fcnn_program_step(prog, mesh)
        """)
    assert "build_fcnn_program_step" in v.message


def test_pragma_suppresses_deprecated_call():
    assert _lint("""
        from repro.exec.runtime import build_train_step
        build_train_step(prog, mesh, opt)  # lint: allow-deprecated
        """) == []


def test_generic_build_train_step_not_flagged():
    """launch.steps.build_train_step (the non-deprecated generic step
    builder) shares a short name with the deprecated shim — only the
    fully qualified deprecated one is flagged."""
    assert _lint("""
        from repro.launch.steps import build_train_step
        build_train_step(model, mesh, settings)
        """) == []
    assert _lint("""
        from repro.launch import steps
        steps.build_train_step(model, mesh, settings)
        """) == []


# -------------------------------------------------------- np-random-in-jit

def test_flags_np_random_in_jitted_body():
    (v,) = _lint("""
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return x + np.random.normal()
        """)
    assert v.rule == "np-random-in-jit"
    assert "np.random" in v.message or "numpy.random" in v.message


def test_flags_np_random_in_shard_map_target():
    (v,) = _lint("""
        import numpy as np
        from jax.experimental.shard_map import shard_map

        def body(x):
            return x * np.random.rand()

        f = shard_map(body, mesh=m, in_specs=s, out_specs=s)
        """)
    assert v.rule == "np-random-in-jit"


def test_np_random_outside_jit_is_fine():
    assert _lint("""
        import numpy as np

        def make_batch(rng):
            return np.random.default_rng(0).normal(size=(8, 4))
        """) == []


def test_pragma_suppresses_np_random():
    assert _lint("""
        import jax
        import numpy as np

        @jax.jit
        def step(x):
            return x + np.random.normal()  # lint: allow-np-random
        """) == []


# --------------------------------------------------------- repo-wide pass

def test_kernel_coverage_on_this_repo():
    """Every kernel module under src/repro/kernels/ is referenced by some
    oracle test — the rule that keeps new Pallas kernels pinned."""
    assert check_kernel_coverage(REPO_ROOT) == []


def test_repo_lints_clean(capsys):
    """``make lint`` equivalent: the whole repo passes all three rules."""
    assert main(["--root", REPO_ROOT]) == 0
    out = capsys.readouterr().out
    assert "lint: OK" in out


def test_main_reports_violations(tmp_path, capsys):
    src = tmp_path / "src"
    src.mkdir()
    (src / "bad.py").write_text(
        "from repro.exec.runtime import build_train_step\n"
        "build_train_step(p, m, o)\n")
    assert main(["--root", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "[deprecated-call]" in out
    assert "bad.py:2" in out
