"""Per-device static program analyzer (ISSUE 9): expansion, happens-before
deadlock detection, chunk-level memory walk, shape abstract interpretation
— and the seeded corruption corpus that ``validate_program`` passes but
``analyze_program`` must reject with a precise error."""

import dataclasses
import json

import pytest

from repro.configs.nn_benchmarks import NN_BENCHMARKS, onoc_config, workload
from repro.core.allocation import MappingStrategy
from repro.exec.analysis import (
    LEVELS,
    DeviceOp,
    ProgramAnalysisError,
    analyze_program,
    check_memory,
    corruption_corpus,
    expand_program,
    n_device_ops,
)
from repro.exec.program import Opcode, PeriodProgram, compile_fcnn_program
from repro.exec.validate import ProgramValidationError, validate_program
from repro.launch.mesh import make_test_mesh

import repro.exec as rexec

N_DEV = 8
W = workload("NN1", batch_size=8)
CFG = onoc_config(lambda_max=64)

PROG = compile_fcnn_program(W, CFG, N_DEV, "orrm")
CORPUS = corruption_corpus(PROG, seed=0)


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(N_DEV)


# ------------------------------------------------------------- clean pass

@pytest.mark.parametrize("residency", ["sharded", "replicated"])
@pytest.mark.parametrize("strategy", list(MappingStrategy))
@pytest.mark.parametrize("name", sorted(NN_BENCHMARKS))
def test_compiled_programs_analyze_clean(name, strategy, residency, mesh):
    """Acceptance sweep: every program produced by ``repro.exec.compile``
    for NN1..NN6 x {fm,rrm,orrm} x {sharded,replicated} passes the full
    analyzer (``analyze="full"`` is the compile default)."""
    w = workload(name, batch_size=8)
    exe = rexec.compile(w, CFG, mesh, strategy, residency=residency)
    report = analyze_program(exe.program, w, CFG, level="full")
    assert report.level == "full"
    assert report.n_devices == N_DEV
    assert report.n_instructions == len(exe.program.instructions)
    assert report.checks == ("validate", "expand", "endpoints",
                             "happens-before", "memory", "shapes")
    assert report.n_hb_edges > report.n_device_ops > 0


def test_analyze_levels():
    assert analyze_program(PROG, level="off") is None
    fast = analyze_program(PROG, level="fast")
    assert "shapes" not in fast.checks
    full = analyze_program(PROG, W, CFG, level="full")
    assert "shapes" in full.checks
    assert full.n_hb_edges == fast.n_hb_edges
    with pytest.raises(ValueError, match="analyze level"):
        analyze_program(PROG, level="bogus")
    assert LEVELS == ("off", "fast", "full")


def test_analysis_error_is_a_validation_error():
    """One error taxonomy: handlers catching ProgramValidationError keep
    working when the analyzer is switched on."""
    assert issubclass(ProgramAnalysisError, ProgramValidationError)


def test_validate_program_delegates_to_analyzer():
    validate_program(PROG, W, CFG, analyze="full")
    corrupted = CORPUS[0].program
    validate_program(corrupted, W, CFG)  # SPMD validator alone: blind
    with pytest.raises(ProgramAnalysisError, match=CORPUS[0].match):
        validate_program(corrupted, W, CFG, analyze="fast")


def test_compile_rejects_bad_analyze_level(mesh):
    with pytest.raises(ValueError, match="analyze level"):
        rexec.compile(W, CFG, mesh, "orrm", analyze="bogus")


# -------------------------------------------------------------- expansion

def test_expansion_covers_every_device_in_program_order():
    streams = expand_program(PROG)
    assert sorted(streams) == list(range(N_DEV))
    assert n_device_ops(streams) == sum(
        len(i.devices) for i in PROG.instructions)
    for d, ops in streams.items():
        assert all(op.device == d for op in ops)
        indices = [op.index for op in ops]
        assert indices == sorted(indices)  # program order preserved


def test_expansion_resolves_chunks_and_endpoints():
    streams = expand_program(PROG)
    recvs = {i.period: i for i in PROG.instructions
             if i.opcode is Opcode.RECV}
    for ins in PROG.instructions:
        if ins.opcode is Opcode.RUN:
            for j, d in enumerate(ins.devices):
                op = next(o for o in streams[d]
                          if o.op == "run" and o.period == ins.period)
                assert op.chunk == j  # chunk j computed by window[j]
                assert op.chunk_width == ins.chunk_width
        elif ins.opcode is Opcode.SEND:
            recv = recvs[ins.period]
            for d in ins.devices:
                op = next(o for o in streams[d]
                          if o.op == "send" and o.period == ins.period)
                assert op.peers == tuple(recv.devices)
        elif ins.opcode is Opcode.RECV:
            for d in ins.devices:
                op = next(o for o in streams[d]
                          if o.op == "recv" and o.period == ins.period)
                assert op.peers == tuple(ins.sources)


def test_device_stream_helpers():
    for d in range(N_DEV):
        stream = PROG.device_stream(d)
        assert all(d in i.devices for i in stream)
    assert sorted(PROG.device_streams()) == list(range(N_DEV))
    with pytest.raises(ValueError, match="device 8 out of range"):
        PROG.device_stream(N_DEV)
    with pytest.raises(ValueError, match="out of range"):
        PROG.device_stream(-1)


def test_recv_sources_survive_json_roundtrip():
    back = PeriodProgram.from_json(json.loads(json.dumps(PROG.to_json())))
    for a, b in zip(PROG.instructions, back.instructions):
        assert a.sources == b.sources
    analyze_program(back, W, CFG, level="full")


def test_recv_without_sources_derives_from_send():
    """Programs serialized before the ``sources`` annotation existed
    still analyze: endpoints fall back to the same-period SEND window."""
    stripped = dataclasses.replace(PROG, instructions=tuple(
        dataclasses.replace(i, sources=())
        if i.opcode is Opcode.RECV else i
        for i in PROG.instructions))
    report = analyze_program(stripped, W, CFG, level="full")
    assert report is not None


# ------------------------------------------------------ corruption corpus

def test_corpus_is_complete_and_deterministic():
    assert [e.name for e in CORPUS] == [
        "deadlocked-send-cycle",
        "swapped-recv-source",
        "free-before-last-use",
        "shape-mismatched-run-batch",
        "shape-mismatched-run-activation",
    ]
    again = corruption_corpus(PROG, seed=0)
    assert [(e.name, e.description) for e in again] == \
           [(e.name, e.description) for e in CORPUS]


@pytest.mark.parametrize("entry", CORPUS, ids=lambda e: e.name)
def test_corpus_passes_validator_but_analyzer_rejects(entry):
    """The whole point of the corpus: each corruption sits in a blind
    spot of the SPMD validator and only the per-device analyzer sees it."""
    validate_program(entry.program, W, CFG)
    with pytest.raises(ProgramAnalysisError, match=entry.match):
        analyze_program(entry.program, W, CFG, level="full")


@pytest.mark.parametrize(
    "entry",
    [e for e in CORPUS if not e.name.startswith("shape-")],
    ids=lambda e: e.name)
def test_structural_corruptions_rejected_at_fast_level(entry):
    """Deadlock/endpoint/memory corruptions need no workload: level
    ``"fast"`` (no cost contract, no shape interpreter) catches them."""
    with pytest.raises(ProgramAnalysisError, match=entry.match):
        analyze_program(entry.program, level="fast")


def test_deadlock_message_names_the_cycle():
    entry = next(e for e in CORPUS if e.name == "deadlocked-send-cycle")
    with pytest.raises(ProgramAnalysisError) as err:
        analyze_program(entry.program, level="fast")
    msg = str(err.value)
    assert "deadlock" in msg
    assert "RECV period" in msg and "SEND period" in msg  # cycle chain
    assert "device" in msg


def test_corpus_errors_name_device_and_period():
    for entry in CORPUS:
        with pytest.raises(ProgramAnalysisError) as err:
            analyze_program(entry.program, W, CFG, level="full")
        assert "period" in str(err.value), entry.name


# ----------------------------------------------- memory walk (synthetic)

def _run(d, idx, period, layer, phase="fp", **kw):
    return DeviceOp(device=d, index=idx, op="run", period=period,
                    layer=layer, phase=phase, chunk=0, chunk_width=1, **kw)


def test_check_memory_rejects_double_window_free():
    ops = (
        _run(0, 0, 1, 1),
        DeviceOp(device=0, index=1, op="free", period=1,
                 free_kind="window"),
        DeviceOp(device=0, index=2, op="free", period=1,
                 free_kind="window"),
    )
    with pytest.raises(ProgramAnalysisError,
                       match="double FREE.*device 0.*freed at period 1"):
        check_memory({0: ops}, l=1, fp_windows={1: (0,)},
                     check_params=False)


def test_check_memory_rejects_param_double_free_and_leak():
    free = DeviceOp(device=0, index=2, op="free", period=2, layer=1,
                    free_kind="param")
    with pytest.raises(ProgramAnalysisError, match="double FREE: param"):
        check_memory({0: (_run(0, 0, 1, 1), free,
                          dataclasses.replace(free, index=3, period=3))},
                     l=1, fp_windows={1: (0,)})
    with pytest.raises(ProgramAnalysisError,
                       match="residency leak: device 0"):
        check_memory({0: (_run(0, 0, 1, 1),)}, l=1, fp_windows={1: (0,)})


def test_check_memory_rejects_run_after_param_free():
    ops = (
        _run(0, 0, 1, 1),
        DeviceOp(device=0, index=1, op="free", period=1, layer=1,
                 free_kind="param"),
        _run(0, 2, 2, 1, phase="bp"),
    )
    with pytest.raises(ProgramAnalysisError,
                       match="use-after-FREE: RUN period 2"):
        check_memory({0: ops}, l=1, fp_windows={1: (0,)})
