"""RWA schedule validity (paper Section 4.6 / Fig. 6)."""

from hypothesis import given, strategies as st

from repro.core.allocation import MappingStrategy, map_cores
from repro.core.onoc_model import FCNNWorkload, ONoCConfig
from repro.core.wavelength import UNASSIGNED, assign_wavelengths, schedule_epoch


@given(st.integers(1, 40), st.integers(1, 40), st.sampled_from([2, 8, 64]),
       st.integers(45, 100))
def test_schedule_covers_all_senders_once(n_send, n_recv, lam, m):
    senders = list(range(n_send))
    receivers = list(range(40, 40 + n_recv))
    ws = assign_wavelengths(senders, receivers, lam, m + 60)
    # TDM slot count is exactly Eq. (6)'s ceiling
    assert ws.n_slots == -(-len(senders) // lam)
    seen = [s for slot in ws.slots for s in slot.senders]
    assert sorted(seen) == sorted(set(senders))
    for slot in ws.slots:
        # within a slot wavelengths are distinct and within budget
        assert len(set(slot.wavelengths)) == len(slot.senders) <= lam


@given(st.integers(2, 30), st.integers(2, 30), st.sampled_from([2, 8]))
def test_wm_matrix_consistency(n_send, n_recv, lam):
    m = 80
    senders = list(range(n_send))
    receivers = list(range(40, 40 + n_recv))
    ws = assign_wavelengths(senders, receivers, lam, m)
    for slot in ws.slots:
        for s, w in zip(slot.senders, slot.wavelengths):
            for r in receivers:
                if r != s:
                    assert ws.wm[s, r] == w
    # no assignments outside the sender/receiver sets
    for i in range(m):
        for j in range(m):
            if ws.wm[i, j] != UNASSIGNED:
                assert i in senders and j in receivers


def test_epoch_schedule_structure():
    w = FCNNWorkload([64, 128, 96, 10], batch_size=1)
    cfg = ONoCConfig(m=100, lambda_max=8)
    mp = map_cores(w, cfg, MappingStrategy.RRM)
    schedules = schedule_epoch(mp, cfg.lambda_max)
    # communicating transitions: 1..l-1 (FP) and l+1..2l-1 (BP)
    periods = [s.period for s in schedules]
    l = w.l
    assert periods == [i for i in range(1, 2 * l) if i != l]
    for s in schedules:
        assert s.direction == ("cw" if s.period < l else "ccw")
