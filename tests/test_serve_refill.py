"""Mid-stream refill isolation (ISSUE 10 satellite — the bug fix).

The old ``launch/serve.py`` prototype refilled free slots by re-running a
*whole-batch* prefill, overwriting the shared cache and corrupting every
in-flight request's KV state.  The promoted runner prefills batch-1 and
merges only the admitted slot's cache rows, so these tests pin, on the
real smoke model:

  * admitting a new request mid-decode leaves an in-flight slot's token
    stream bit-identical to a run where the admission never happened;
  * the cache merge touches exactly the admitted slot's rows (direct
    per-leaf comparison along the ``cache_batch`` axis);
  * the engine-level corollary: scheduled streams are independent of
    slot count.
"""

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.serve.runner import JaxModelRunner, snap_prompt_buckets
from repro.serve.scheduler import ServingEngine, TickClock
from repro.serve.traffic import make_traffic, scenario_preset

ARCH = "qwen3-14b"
MAX_LEN = 24


@pytest.fixture(scope="module")
def cfg():
    return smoke_config(ARCH)


def _prompt(seed: int, n: int, vocab: int) -> np.ndarray:
    return np.random.default_rng(seed).integers(
        0, vocab, size=n).astype(np.int32)


def _decode_slot(runner: JaxModelRunner, streams: dict[int, list[int]],
                 steps: int) -> None:
    """Advance every stream in ``streams`` by ``steps`` batched decodes."""
    for _ in range(steps):
        last = np.zeros(runner.n_slots, np.int32)
        for slot, toks in streams.items():
            last[slot] = toks[-1]
        nxt = runner.decode(last)
        for slot in streams:
            streams[slot].append(int(nxt[slot]))


def test_mid_stream_admission_leaves_inflight_stream_unchanged(cfg):
    pa = _prompt(0, 8, cfg.vocab_size)
    pb = _prompt(1, 8, cfg.vocab_size)

    # reference: request A alone, 6 decode steps
    solo = JaxModelRunner(cfg, n_slots=2, max_len=MAX_LEN)
    ref = {0: [solo.prefill(0, pa)]}
    _decode_slot(solo, ref, 6)

    # same model: A decodes 3 steps, then B is admitted into slot 1
    # mid-stream, then A decodes 3 more steps
    shared = JaxModelRunner(cfg, n_slots=2, max_len=MAX_LEN)
    streams = {0: [shared.prefill(0, pa)]}
    _decode_slot(shared, streams, 3)
    streams[1] = [shared.prefill(1, pb)]       # the mid-stream admission
    _decode_slot(shared, streams, 3)

    assert streams[0] == ref[0], (
        "admitting B mid-decode changed A's tokens — the whole-batch "
        "refill bug is back")
    # and B's stream matches B served alone from the same model state
    solo_b = JaxModelRunner(cfg, n_slots=2, max_len=MAX_LEN)
    ref_b = {1: [solo_b.prefill(1, pb)]}
    _decode_slot(solo_b, ref_b, 3)
    assert streams[1] == ref_b[1]


def test_cache_merge_touches_only_the_admitted_slots_rows(cfg):
    runner = JaxModelRunner(cfg, n_slots=3, max_len=MAX_LEN)
    runner.prefill(0, _prompt(0, 8, cfg.vocab_size))
    before = jax.tree.map(np.asarray, runner.cache)   # host copy

    runner.prefill(2, _prompt(2, 8, cfg.vocab_size))
    after = jax.tree.map(np.asarray, runner.cache)

    axes = runner.model.cache_axes()
    leaves, treedef = jax.tree_util.tree_flatten(before)
    leaves_after = treedef.flatten_up_to(after)
    leaves_axes = treedef.flatten_up_to(axes)
    touched = 0
    for b, a, ax in zip(leaves, leaves_after, leaves_axes):
        i = list(ax).index("cache_batch")
        # slot 0 (in-flight) and slot 1 (empty) rows are bit-identical
        np.testing.assert_array_equal(np.take(b, 0, axis=i),
                                      np.take(a, 0, axis=i))
        np.testing.assert_array_equal(np.take(b, 1, axis=i),
                                      np.take(a, 1, axis=i))
        if not np.array_equal(np.take(b, 2, axis=i), np.take(a, 2, axis=i)):
            touched += 1
    assert touched > 0            # the merge did write slot 2 somewhere


def test_engine_streams_independent_of_slot_count(cfg):
    sc = scenario_preset("steady", n_requests=4, prompt_buckets=(8,),
                         gen_buckets=(4,))
    trace = make_traffic(sc, seed=0)

    def serve(n_slots: int):
        runner = JaxModelRunner(cfg, n_slots=n_slots, max_len=sc.max_len)
        engine = ServingEngine(runner, n_slots=n_slots, clock=TickClock(0.01))
        return engine.run(trace, sc)

    r1, r3 = serve(1), serve(3)
    assert r1.streams == r3.streams
    assert set(r1.streams) == set(trace.rids)


def test_prefill_guards(cfg):
    runner = JaxModelRunner(cfg, n_slots=2, max_len=MAX_LEN)
    with pytest.raises(IndexError, match="slot"):
        runner.prefill(5, _prompt(0, 8, cfg.vocab_size))
    with pytest.raises(ValueError, match="max_len"):
        runner.prefill(0, _prompt(0, MAX_LEN, cfg.vocab_size))
    with pytest.raises(ValueError, match="token-LM"):
        JaxModelRunner(smoke_config("qwen2-vl-72b"), n_slots=2,
                       max_len=MAX_LEN)


def test_snap_prompt_buckets_rounds_to_ssm_chunk():
    dense = smoke_config(ARCH)
    assert snap_prompt_buckets(dense, (16, 8, 8, 32)) == (8, 16, 32)
    ssm = smoke_config("mamba2-2.7b")          # ssm_chunk == 8
    assert snap_prompt_buckets(ssm, (5, 8, 13)) == (8, 16)
    hybrid = smoke_config("zamba2-1.2b")
    assert snap_prompt_buckets(hybrid, (9,)) == (16,)
