"""Traffic-generator determinism (ISSUE 10 satellite): a trace is a pure
function of (scenario, seed) — bit-identical across runs and independent
of everything downstream (slots, devices, model) — and prompt content is
a pure function of (trace seed, rid, vocab)."""

import dataclasses

import numpy as np
import pytest

from repro.serve.traffic import (
    RequestEvent,
    SCENARIO_NAMES,
    Scenario,
    make_traffic,
    prompt_tokens,
    scenario_preset,
)


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_same_seed_is_bit_identical(name):
    sc = scenario_preset(name)
    a = make_traffic(sc, seed=7)
    b = make_traffic(sc, seed=7)
    assert a.events == b.events           # frozen dataclasses: field equality
    assert a.seed == b.seed and a.scenario == b.scenario == name


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_different_seeds_diverge(name):
    sc = scenario_preset(name)
    a = make_traffic(sc, seed=0)
    b = make_traffic(sc, seed=1)
    assert a.events != b.events


def test_equal_parameter_scenarios_get_distinct_traces():
    # the RNG folds in crc32(name): same fields, different name => new trace
    a = Scenario("alpha", n_requests=8)
    b = Scenario("bravo", n_requests=8)
    ta, tb = make_traffic(a, 0), make_traffic(b, 0)
    assert [e.arrival_s for e in ta.events] != [e.arrival_s for e in tb.events]


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_event_shape_invariants(name):
    sc = scenario_preset(name)
    trace = make_traffic(sc, seed=3)
    assert len(trace) == sc.n_requests
    assert trace.rids == tuple(range(sc.n_requests))
    arrivals = [e.arrival_s for e in trace.events]
    assert arrivals == sorted(arrivals)
    assert all(t > 0 for t in arrivals)
    for e in trace.events:
        assert e.prompt_len in sc.prompt_buckets
        assert e.gen_len in sc.gen_buckets
        assert e.prompt_len + e.gen_len <= sc.max_len


def test_burst_window_densifies_arrivals():
    # 10x multiplier inside [0.2, 0.5): that window must hold more
    # arrivals than the equally long plain-rate window after it
    sc = scenario_preset("burst", n_requests=300)
    trace = make_traffic(sc, seed=0)
    t0, t1, _ = sc.burst
    inside = sum(t0 <= e.arrival_s < t1 for e in trace.events)
    after = sum(t1 <= e.arrival_s < t1 + (t1 - t0) for e in trace.events)
    assert inside > 2 * max(after, 1)


def test_zipf_rank1_bucket_dominates():
    sc = scenario_preset("steady", n_requests=400)
    trace = make_traffic(sc, seed=5)
    counts = {b: 0 for b in sc.prompt_buckets}
    for e in trace.events:
        counts[e.prompt_len] += 1
    first, *rest = sc.prompt_buckets
    assert all(counts[first] > counts[b] for b in rest)


def test_prompt_tokens_pure_function_of_seed_rid_vocab():
    ev = RequestEvent(rid=4, arrival_s=0.1, prompt_len=16, gen_len=4)
    a = prompt_tokens(11, ev, vocab=256)
    b = prompt_tokens(11, ev, vocab=256)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (16,) and a.dtype == np.int32
    assert a.min() >= 0 and a.max() < 256
    # rid and seed both matter
    other = dataclasses.replace(ev, rid=5)
    assert not np.array_equal(a, prompt_tokens(11, other, vocab=256))
    assert not np.array_equal(a, prompt_tokens(12, ev, vocab=256))


def test_preset_overrides_and_validation():
    sc = scenario_preset("steady", n_requests=3, prompt_buckets=(8,))
    assert sc.n_requests == 3 and sc.prompt_buckets == (8,)
    assert scenario_preset("steady") is scenario_preset("steady")
    with pytest.raises(KeyError, match="unknown scenario"):
        scenario_preset("nope")
    with pytest.raises(ValueError):
        Scenario("bad", n_requests=0)
    with pytest.raises(ValueError):
        Scenario("bad", rate_rps=0.0)
    with pytest.raises(ValueError):
        Scenario("bad", gen_buckets=(4, 0))


def test_trace_serialization_round_trip():
    trace = make_traffic(scenario_preset("drain"), seed=2)
    dicts = trace.to_dicts()
    assert [RequestEvent(**d) for d in dicts] == list(trace.events)
    assert trace.duration_s == trace.events[-1].arrival_s
