"""Scheduler invariants (ISSUE 10 satellite), model-free: a FakeRunner +
TickClock drive the real SlotManager/ServingEngine so the invariants are
pinned deterministically without XLA in the loop.

Pinned here:
  * admission is FIFO over arrival order, never double-assigns a slot;
  * every submitted request finishes exactly once (burst + drain
    presets), with exactly gen_len tokens;
  * streams are independent of slot count (continuous-batching refill
    cannot leak state between requests — the FakeRunner keeps per-slot
    state exactly like the per-slot cache merge does);
  * metrics lifecycle: double submit / double finish raise;
  * elastic restarts (device loss, SLO growth) replay identical streams.
"""

import numpy as np
import pytest

from repro.serve.elastic import ReplanDecision
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (
    Request,
    ServingEngine,
    SlotManager,
    TickClock,
    WallClock,
)
from repro.serve.traffic import make_traffic, prompt_tokens, scenario_preset

VOCAB = 64


class FakeRunner:
    """Deterministic per-slot LM stand-in.  First token is a hash of the
    prompt; each decode step advances a per-slot counter seeded by that
    hash — so a request's stream is a pure function of its prompt iff the
    engine never lets another request's admission touch the slot state."""

    def __init__(self, n_slots: int, n_devices: int = 8):
        self.vocab = VOCAB
        self.n_devices = n_devices
        self.n_slots = n_slots
        self.state = np.zeros(n_slots, np.int64)
        self.prefill_log: list[tuple[int, int]] = []   # (slot, prompt hash)
        self.rebuild_log: list[tuple[int, int]] = []

    def prefill(self, slot: int, prompt: np.ndarray) -> int:
        h = int(np.sum(prompt) % self.vocab)
        self.state[slot] = h
        self.prefill_log.append((slot, h))
        return h

    def decode(self, last_tokens: np.ndarray) -> np.ndarray:
        self.state = (self.state + 1) % self.vocab
        return self.state.astype(np.int32)

    def rebuild(self, n_devices=None, n_slots=None):
        if n_devices is not None:
            self.n_devices = n_devices
        if n_slots is not None:
            self.n_slots = n_slots
        self.state = np.zeros(self.n_slots, np.int64)
        self.rebuild_log.append((self.n_devices, self.n_slots))


class StubAutoscaler:
    """Scripted decisions so engine reactions are tested without Lemma-1
    machinery in the loop (the real oracle is covered in
    test_serve_elastic.py)."""

    def __init__(self, n_devices: int, n_slots: int, grow_to: int | None = None):
        self.n_devices = n_devices
        self.n_slots = n_slots
        self.grow_to = grow_to

    def on_device_loss(self, n_lost: int, now: float) -> ReplanDecision:
        d = ReplanDecision("device_loss", now, self.n_devices,
                           self.n_devices - n_lost, self.n_slots,
                           self.n_slots)
        self.n_devices -= n_lost
        return d

    def on_slo_violation(self, now: float, p99: float):
        if self.grow_to is None or self.n_slots >= self.grow_to:
            return None
        d = ReplanDecision("slo_violation", now, self.n_devices,
                           self.n_devices, self.n_slots, self.grow_to)
        self.n_slots = self.grow_to
        return d


def _expected_stream(seed: int, ev) -> list[int]:
    h = int(np.sum(prompt_tokens(seed, ev, VOCAB)) % VOCAB)
    return [(h + i) % VOCAB for i in range(ev.gen_len)]


def _run(name: str, n_slots: int, seed: int = 0, *, autoscaler=None,
         scenario=None, **engine_kw):
    sc = scenario if scenario is not None else scenario_preset(name)
    trace = make_traffic(sc, seed)
    runner = FakeRunner(n_slots)
    engine = ServingEngine(runner, n_slots=n_slots, clock=TickClock(0.01),
                           autoscaler=autoscaler, **engine_kw)
    return engine.run(trace, sc), trace, runner


# ---------------------------------------------------------- SlotManager unit

def test_slot_manager_fifo_and_no_double_assignment():
    mgr = SlotManager(2)
    reqs = [Request(rid=i, prompt=np.zeros(4, np.int32), gen_len=2)
            for i in range(4)]
    for r in reqs:
        mgr.submit(r)
    assigned = mgr.fill()
    assert [(s, r.rid) for s, r in assigned] == [(0, 0), (1, 1)]
    assert mgr.fill() == []               # no free slots, queue untouched
    # a request already resident must never be assigned a second slot:
    # free slot 1 but push the slot-0 resident back onto the queue
    mgr.slots[1] = None
    mgr.queue.appendleft(reqs[0])
    with pytest.raises(RuntimeError, match="already occupies"):
        mgr.fill()


def test_slot_manager_release_and_drain():
    mgr = SlotManager(2)
    for i in range(3):
        mgr.submit(Request(rid=i, prompt=np.zeros(2, np.int32), gen_len=1))
    mgr.fill()
    mgr.slots[0].done = True
    done = mgr.release_done()
    assert [r.rid for r in done] == [0] and mgr.slots[0] is None
    assert [r.rid for r in mgr.finished] == [0]
    # refill takes the queued rid 2; drain pulls both residents out
    mgr.fill()
    drained = mgr.drain_slots()
    assert sorted(r.rid for r in drained) == [1, 2]
    assert mgr.slots == [None, None] and mgr.active is False

    with pytest.raises(ValueError):
        SlotManager(0)


# ------------------------------------------------------------- metrics unit

def test_metrics_double_submit_and_double_finish_raise():
    m = ServeMetrics()
    m.on_submit(1, 0.0, 8, 4)
    with pytest.raises(RuntimeError, match="submitted twice"):
        m.on_submit(1, 0.0, 8, 4)
    m.on_finish(1, 1.0, n_gen=4)
    with pytest.raises(RuntimeError, match="finished twice"):
        m.on_finish(1, 2.0, n_gen=4)
    with pytest.raises(RuntimeError, match="never submitted"):
        m.on_finish(2, 1.0, n_gen=4)


def test_metrics_restart_keeps_first_ttft():
    m = ServeMetrics()
    m.on_submit(0, 0.0, 8, 4)
    m.on_admit(0, 0.1)
    m.on_first_token(0, 0.2)
    m.on_restart(0)
    m.on_admit(0, 5.0)          # re-admission after restart: ignored
    m.on_first_token(0, 5.1)
    m.on_finish(0, 6.0, n_gen=4)
    rec = m.records[0]
    assert rec.admit_s == 0.1 and rec.first_token_s == 0.2
    assert rec.restarts == 1
    assert m.report().n_restarts == 1


# ------------------------------------------------------------- engine runs

@pytest.mark.parametrize("name", ["burst", "drain"])
def test_every_request_finishes_exactly_once(name):
    result, trace, _ = _run(name, n_slots=3)
    assert set(result.streams) == set(trace.rids)
    assert result.slo.n_finished == len(trace)
    for ev in trace.events:
        assert len(result.streams[ev.rid]) == ev.gen_len
    # finished exactly once: the metrics guard would have raised otherwise,
    # and every record carries a finish timestamp
    assert all(r.finish_s is not None
               for r in result.metrics.records.values())


def test_admission_is_fifo_over_arrival_order():
    # drain: everything arrives nearly at once, 1 slot => admissions must
    # replay exact arrival (== rid) order
    result, trace, runner = _run("drain", n_slots=1)
    hashes = [int(np.sum(prompt_tokens(trace.seed, ev, VOCAB)) % VOCAB)
              for ev in trace.events]
    assert [h for _, h in runner.prefill_log] == hashes
    assert all(s == 0 for s, _ in runner.prefill_log)


@pytest.mark.parametrize("name", ["steady", "burst", "drain"])
def test_streams_are_pure_functions_of_prompts(name):
    result, trace, _ = _run(name, n_slots=3)
    for ev in trace.events:
        assert result.streams[ev.rid] == _expected_stream(trace.seed, ev)


def test_streams_independent_of_slot_count():
    r1, trace, _ = _run("burst", n_slots=1)
    r4, _, _ = _run("burst", n_slots=4)
    assert r1.streams == r4.streams
    # more slots can only help wall-clock, never change tokens
    assert r4.n_decode_steps <= r1.n_decode_steps


def test_device_loss_restarts_replay_identical_streams():
    sc = scenario_preset("device-loss-mid-decode", n_requests=8)
    auto = StubAutoscaler(n_devices=8, n_slots=3)
    faulted, trace, runner = _run(sc.name, 3, autoscaler=auto, scenario=sc)
    clean, _, _ = _run(sc.name, 3, scenario=sc.replace(device_loss=None))
    assert faulted.streams == clean.streams
    assert [r.reason for r in faulted.replans] == ["device_loss"]
    assert faulted.replans[0].to_devices == 6
    assert runner.rebuild_log == [(6, 3)]
    assert faulted.slo.n_restarts >= 1


def test_slo_violation_grows_slots_and_preserves_streams():
    # sub-nanosecond TTFT target: every finish is a violation; with
    # patience 1 and a check every decode step the engine must consult
    # the autoscaler, grow the batch, and still serve everything
    sc = scenario_preset("steady", ttft_slo_s=1e-9)
    auto = StubAutoscaler(n_devices=8, n_slots=2, grow_to=5)
    grown, trace, runner = _run(sc.name, 2, autoscaler=auto, scenario=sc,
                                slo_check_every=1, slo_patience=1)
    assert [r.reason for r in grown.replans] == ["slo_violation"]
    assert grown.replans[0].to_slots == 5
    assert (8, 5) in runner.rebuild_log
    assert set(grown.streams) == set(trace.rids)
    for ev in trace.events:
        assert grown.streams[ev.rid] == _expected_stream(trace.seed, ev)


# ------------------------------------------------------------------ clocks

def test_tick_clock_and_wall_clock_monotone():
    t = TickClock(0.5)
    assert t.now() == 0.0
    t.advance()
    t.advance(0.25)
    assert t.now() == 0.75
    t.skip_to(0.1)              # never backwards
    assert t.now() == 0.75
    t.skip_to(2.0)
    assert t.now() == 2.0

    w = WallClock()
    a = w.now()
    w.skip_to(a + 10.0)         # idle gap is skipped, not slept
    assert w.now() >= a + 10.0
    w.skip_to(0.0)
    assert w.now() >= a + 10.0
