"""Degraded-mode recovery (ISSUE 7 acceptance): a seeded device loss
mid-epoch on the 8-device CPU ring triggers replanning + checkpoint-resume
and the resumed trajectory matches a from-scratch run on the surviving
mesh — no sample skipped or repeated."""

import dataclasses
import tempfile

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.nn_benchmarks import onoc_config
from repro.core.onoc_model import FCNNWorkload
from repro.data import Batcher, fcnn_classification_dataset
from repro.models import fcnn
from repro.optim import adam
from repro.runtime.degraded import DegradedModeRunner
from repro.runtime.faults import FaultEvent, FaultKind, FaultSchedule

SIZES = [32, 16, 8, 10]
BATCH = 8
N_STEPS = 8
N_DEV = 8

W = FCNNWorkload(SIZES, batch_size=BATCH)
CFG = dataclasses.replace(onoc_config(lambda_max=64), m=N_DEV)
X, Y = fcnn_classification_dataset(64, input_dim=SIZES[0], seed=3)


def _run(schedule, n_devices, cfg=None, n_steps=N_STEPS, kernel_mode="ref",
         **kw):
    params0 = fcnn.init(jax.random.PRNGKey(0), SIZES)
    opt = adam(1e-2)
    with tempfile.TemporaryDirectory() as tmp:
        runner = DegradedModeRunner(
            workload=W,
            base_cfg=cfg or dataclasses.replace(CFG, m=n_devices),
            schedule=schedule, checkpointer=Checkpointer(tmp),
            optimizer=opt, n_devices=n_devices, kernel_mode=kernel_mode,
            checkpoint_every=2, backoff_s=0.0, **kw)
        state, history, report = runner.run(
            params0, opt.init(params0),
            Batcher({"x": X, "y": Y}, batch_size=BATCH), n_steps)
    return runner, state, history, report


def test_device_loss_replan_resume_matches_from_scratch():
    """8 -> 6 devices at step 4: replan, resume from checkpoint, and the
    per-step losses + final params match a fault-free 6-device run."""
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=4, period=2, device=6),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=4, period=2, device=7),))
    runner, state, _, report = _run(sched, N_DEV)

    assert len(report.replans) == 1
    rp = report.replans[0]
    assert rp["from_devices"] == 8 and rp["to_devices"] == 6
    assert rp["lost"] == [6, 7]
    assert report.resumed_from == [3]      # checkpoint at steps 1, 3
    assert int(state["step"]) == N_STEPS
    assert sorted(runner.losses) == list(range(N_STEPS))

    scratch, state2, _, report2 = _run(FaultSchedule(), 6)
    assert report2.replans == []
    for s in range(N_STEPS):
        np.testing.assert_allclose(runner.losses[s], scratch.losses[s],
                                   rtol=1e-4, atol=1e-6)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(state2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=5e-4)


def test_seeded_device_loss_scenario_recovers():
    """The exact seeded scenario CI runs (fault-smoke)."""
    sched = FaultSchedule.seeded_device_loss(
        0, n_steps=N_STEPS, n_devices=N_DEV, n_periods=2 * W.l)
    runner, state, _, report = _run(sched, N_DEV)
    assert len(report.replans) == 1
    assert report.replans[0]["to_devices"] == N_DEV - len(sched.events)
    assert int(state["step"]) == N_STEPS


def test_loss_before_first_checkpoint_restarts_from_scratch():
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=1, device=7),))
    runner, state, _, report = _run(sched, N_DEV)
    assert report.resumed_from == [-1]     # no checkpoint existed yet
    assert int(state["step"]) == N_STEPS
    scratch, _, _, _ = _run(FaultSchedule(), 7)
    for s in range(N_STEPS):
        np.testing.assert_allclose(runner.losses[s], scratch.losses[s],
                                   rtol=1e-4, atol=1e-6)


def test_transient_run_fault_is_retried_not_fatal():
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=2, period=1,
                   device=0, count=2),))
    runner, state, history, report = _run(sched, N_DEV)
    assert report.retries == 2
    assert report.replans == []
    assert int(state["step"]) == N_STEPS
    scratch, _, _, _ = _run(FaultSchedule(), N_DEV)
    for s in range(N_STEPS):
        np.testing.assert_allclose(runner.losses[s], scratch.losses[s],
                                   rtol=1e-6, atol=1e-7)


def test_kernel_failure_degrades_to_ref_path():
    """kernel_mode="pallas" cannot lower on CPU: the runner must fall back
    to the reference path once and finish training."""
    runner, state, _, report = _run(FaultSchedule(), N_DEV,
                                    kernel_mode="pallas", n_steps=3)
    assert report.kernel_fallbacks == 1
    assert runner.executor.kernel_mode == "ref"
    assert int(state["step"]) == 3
    scratch, _, _, _ = _run(FaultSchedule(), N_DEV, n_steps=3)
    for s in range(3):
        np.testing.assert_allclose(runner.losses[s], scratch.losses[s],
                                   rtol=1e-6, atol=1e-7)


def test_sharded_residency_recovery_matches_replicated():
    """ISSUE 8: the runner's weight-sharded path survives device loss —
    replan re-derives the survivor ring's chunk geometry and the resumed
    trajectory is bit-identical to the replicated-residency run (canonical
    state stays in full layout, so checkpoints are layout-portable)."""
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=4, period=2, device=6),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=4, period=2, device=7),))
    sharded, state_s, _, rep_s = _run(sched, N_DEV, residency="sharded")
    repl, state_r, _, rep_r = _run(sched, N_DEV, residency="replicated")

    assert len(rep_s.replans) == 1
    assert rep_s.replans[0]["to_devices"] == 6
    assert sharded.executable.residency == "sharded"
    assert int(state_s["step"]) == N_STEPS
    for s in range(N_STEPS):
        assert sharded.losses[s] == repl.losses[s]
    for a, b in zip(jax.tree.leaves(state_s["params"]),
                    jax.tree.leaves(state_r["params"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_straggler_and_degrade_events_are_recorded_not_fatal():
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.STRAGGLER, step=1, period=2,
                   magnitude=2.0),
        FaultEvent(kind=FaultKind.WAVELENGTH_DEGRADE, step=2, period=1,
                   magnitude=0.5),))
    runner, state, _, report = _run(sched, N_DEV)
    assert report.straggles == 1
    assert {f["kind"] for f in report.fired} == {
        "straggler", "wavelength_degrade"}
    assert int(state["step"]) == N_STEPS
