"""Fault schedules, runtime injection, and fault-aware epoch pricing
(ISSUE 7 tentpole: src/repro/runtime/faults.py)."""

import dataclasses

import pytest

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.simulator import ENoCBackend, ONoCBackend, simulate_epoch
from repro.exec.program import compile_fcnn_program, Opcode
from repro.runtime.faults import (
    DeviceLossFault,
    EpochFaults,
    FaultEvent,
    FaultInjector,
    FaultKind,
    FaultSchedule,
    TransientRunFault,
    expected_epoch_time,
)


W = workload("NN1", batch_size=64)
CFG = onoc_config(lambda_max=64)


# ----------------------------------------------------------------- schedules


def test_sample_is_deterministic():
    rates = {FaultKind.TRANSIENT_RUN: 0.3, FaultKind.STRAGGLER: 0.3,
             FaultKind.DEVICE_LOSS: 0.1}
    a = FaultSchedule.sample(7, n_steps=50, n_devices=8, n_periods=6,
                             rates=rates)
    b = FaultSchedule.sample(7, n_steps=50, n_devices=8, n_periods=6,
                             rates=rates)
    assert a.events == b.events and len(a.events) > 0
    c = FaultSchedule.sample(8, n_steps=50, n_devices=8, n_periods=6,
                             rates=rates)
    assert c.events != a.events


def test_seeded_device_loss_is_mid_run_and_replayable():
    for seed in range(20):
        s = FaultSchedule.seeded_device_loss(seed, n_steps=30, n_devices=8,
                                             n_periods=6, n_lost=2)
        assert s.events == FaultSchedule.seeded_device_loss(
            seed, n_steps=30, n_devices=8, n_periods=6, n_lost=2).events
        assert len(s.events) == 2
        devs = [e.device for e in s.events]
        assert len(set(devs)) == 2           # without replacement
        for e in s.events:
            assert 10 <= e.step <= 20        # middle third
            assert 1 <= e.period <= 6
            assert e.kind is FaultKind.DEVICE_LOSS


def test_at_filters_by_step_and_period():
    ev = (FaultEvent(kind=FaultKind.STRAGGLER, step=3, period=2),
          FaultEvent(kind=FaultKind.STRAGGLER, step=3, period=4),
          FaultEvent(kind=FaultKind.STRAGGLER, step=5, period=2))
    s = FaultSchedule(events=ev)
    assert s.at(3) == ev[:2]
    assert s.at(3, period=4) == (ev[1],)
    assert s.at(4) == ()


# ------------------------------------------------------------------ injector


def _program_instrs():
    prog = compile_fcnn_program(W, CFG, 8, "orrm")
    return prog.instructions


def test_transient_fires_exactly_count_times():
    instrs = _program_instrs()
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=1,
                   device=0, count=2),))
    inj = FaultInjector(sched)

    def attempt():
        for ins in instrs:
            inj.instruction_boundary(0, ins)

    with pytest.raises(TransientRunFault):
        attempt()
    with pytest.raises(TransientRunFault):
        attempt()
    attempt()  # count exhausted: clean pass
    assert inj.report.retries == 2
    assert len(inj.report.fired) == 2


def test_device_losses_aggregate_into_one_fault():
    instrs = _program_instrs()
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=2, period=1, device=6),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=2, period=1, device=7),))
    inj = FaultInjector(sched)
    for ins in instrs:           # step without the fault: nothing fires
        inj.instruction_boundary(0, ins)
    with pytest.raises(DeviceLossFault) as ei:
        for ins in instrs:
            inj.instruction_boundary(2, ins)
    assert ei.value.devices == (6, 7)
    assert ei.value.step == 2 and ei.value.period == 1


def test_period_zero_fires_at_first_run_boundary():
    instrs = _program_instrs()
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=0),))
    inj = FaultInjector(sched)
    first = next(i for i in instrs if i.opcode is Opcode.RUN)
    with pytest.raises(TransientRunFault):
        inj.instruction_boundary(0, first)


def test_timeout_hook():
    fired = []
    inj = FaultInjector(FaultSchedule(), timeout_s=0.5,
                        on_timeout=lambda s, d: fired.append((s, d)))
    inj.observe_step(0, 0.1)
    inj.observe_step(1, 0.9)
    assert inj.report.timeouts == 1 and fired == [(1, 0.9)]


# ------------------------------------------------------------------- pricing


@pytest.mark.parametrize("backend", [ONoCBackend(), ENoCBackend()])
def test_degradations_inflate_epoch_price(backend):
    nominal = simulate_epoch(W, CFG, backend=backend)
    for ef in (EpochFaults(wavelength_loss=0.5),
               EpochFaults(link_degrade={0: 0.5}),
               EpochFaults(straggle={0: 2.0})):
        deg = simulate_epoch(W, CFG, backend=backend, faults=ef)
        if ef.wavelength_loss and backend.name == "enoc":
            continue  # ENoC has no WDM comb to lose
        assert deg.total_s > nominal.total_s
    # no-fault EpochFaults is exactly the nominal price
    same = simulate_epoch(W, CFG, backend=backend, faults=EpochFaults())
    assert same.total_s == nominal.total_s


def test_straggler_scales_only_its_period():
    ef = EpochFaults(straggle={2: 3.0})
    nominal = simulate_epoch(W, CFG)
    deg = simulate_epoch(W, CFG, faults=ef)
    for p, (a, b) in enumerate(zip(nominal.per_period_compute_s,
                                   deg.per_period_compute_s), start=1):
        if p == 2:
            assert b == pytest.approx(3.0 * a)
        else:
            assert b == a


@pytest.mark.parametrize("backend", [ONoCBackend(), ENoCBackend()])
def test_expected_epoch_time_decomposition(backend):
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=3, device=0),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=3, device=1),))
    pr = expected_epoch_time(W, CFG, sched, step=0, backend=backend)
    assert pr.survivors == CFG.m - 2
    assert pr.loss_period == 3
    assert pr.expected_s == pytest.approx(
        pr.prefix_s + pr.re_transition_s + pr.replanned_epoch_s)
    assert pr.expected_s > pr.nominal_s > 0
    assert pr.overhead_pct > 0
    # no device loss: expected == degraded
    pr0 = expected_epoch_time(W, CFG, FaultSchedule(), backend=backend)
    assert pr0.expected_s == pr0.degraded_s == pr0.nominal_s
    assert pr0.loss_period is None


@pytest.mark.parametrize("backend", [ONoCBackend(), ENoCBackend()])
def test_transient_retries_are_priced(backend):
    """ISSUE 8 satellite: TRANSIENT_RUN retries inflate expected_s by the
    re-done degraded prefix through the failed period, count times."""
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=2,
                   device=0, count=2),))
    pr = expected_epoch_time(W, CFG, sched, step=0, backend=backend)
    pr0 = expected_epoch_time(W, CFG, FaultSchedule(), backend=backend)
    assert pr.retries == 2
    assert pr.retry_s > 0.0
    assert pr.expected_s == pytest.approx(pr.degraded_s + pr.retry_s)
    assert pr.expected_s > pr0.expected_s
    # degraded/nominal prices are untouched by retry accounting
    assert pr.degraded_s == pr0.degraded_s == pr.nominal_s
    # the wasted work is exactly count x (compute of periods 1..2 +
    # transitions before period 2) of the degraded epoch
    nominal = simulate_epoch(W, CFG, strategy="orrm", backend=backend)
    want = 2 * (sum(nominal.per_period_compute_s[:2])
                + sum(t.comm_s for t in nominal.transitions if t.period < 2))
    assert pr.retry_s == pytest.approx(want)


@pytest.mark.parametrize("backend", [ONoCBackend(), ENoCBackend()])
def test_transient_pricing_with_device_loss(backend):
    """Only transients strictly before the loss boundary are priced; the
    decomposition gains a retry term."""
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=1,
                   device=2, count=1),
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=5,
                   device=3, count=4),   # at/after the boundary: unpriced
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=3, device=0),))
    pr = expected_epoch_time(W, CFG, sched, step=0, backend=backend)
    assert pr.loss_period == 3
    assert pr.retries == 1
    assert pr.retry_s == pytest.approx(
        simulate_epoch(W, CFG, strategy="orrm",
                       backend=backend).per_period_compute_s[0])
    assert pr.expected_s == pytest.approx(
        pr.prefix_s + pr.retry_s + pr.re_transition_s
        + pr.replanned_epoch_s)


def test_period_zero_transient_prices_first_run():
    """Unpinned (period-0) transients fire at the first RUN boundary and
    are priced as one re-done period-1 RUN."""
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=0),))
    pr = expected_epoch_time(W, CFG, sched, step=0)
    assert pr.retries == 1
    assert pr.retry_s == pytest.approx(
        simulate_epoch(W, CFG).per_period_compute_s[0])


@pytest.mark.parametrize("backend", [ONoCBackend(), ENoCBackend()])
@pytest.mark.parametrize("strategy", ["fm", "rrm", "orrm"])
def test_retry_pricing_matches_simulate_under_same_strategy(backend,
                                                            strategy):
    """ISSUE 9 satellite (the PR-8 footgun): ``expected_epoch_time``
    defaults to ORRM while ``simulate_epoch`` defaults to FM, so a retry
    cross-check silently mismatches unless both use one strategy.  The
    pricing must carry its normalized strategy and its retry term must
    equal the re-done prefix of a simulation under *that* strategy, for
    every strategy x backend."""
    from repro.core.allocation import MappingStrategy

    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=3,
                   device=0, count=2),))
    pr = expected_epoch_time(W, CFG, sched, step=0, strategy=strategy,
                             backend=backend)
    assert pr.strategy == strategy
    # enum input normalizes to the same value
    pr_enum = expected_epoch_time(W, CFG, sched, step=0,
                                  strategy=MappingStrategy(strategy),
                                  backend=backend)
    assert pr_enum.strategy == strategy
    trace = simulate_epoch(W, CFG, strategy=strategy, backend=backend)
    want = 2 * (sum(trace.per_period_compute_s[:3])
                + sum(t.comm_s for t in trace.transitions if t.period < 3))
    assert pr.retry_s == pytest.approx(want)
    assert pr.expected_s == pytest.approx(pr.degraded_s + pr.retry_s)


def test_fault_pricing_rejects_unknown_strategy():
    with pytest.raises(ValueError, match="MappingStrategy|not a valid"):
        expected_epoch_time(W, CFG, FaultSchedule(), strategy="zigzag")


def test_cross_strategy_retry_prefixes_differ_on_enoc():
    """The footgun is real: the same transient's retry price differs
    across strategies on ENoC (placement changes transition comm), so a
    cross-strategy comparison would silently be wrong."""
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=4,
                   device=0, count=1),))
    prices = {s: expected_epoch_time(W, CFG, sched, step=0, strategy=s,
                                     backend=ENoCBackend()).retry_s
              for s in ("fm", "rrm", "orrm")}
    assert len({round(v, 15) for v in prices.values()}) > 1, prices


def test_expected_epoch_time_rejects_total_loss():
    cfg = dataclasses.replace(CFG, m=2)
    sched = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=1, device=0),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=1, device=1),))
    with pytest.raises(ValueError, match="no surviving cores"):
        expected_epoch_time(W, cfg, sched, step=0)
