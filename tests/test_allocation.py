"""Mapping-strategy properties (paper Section 4, Theorem 2, Algorithm 1)."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.allocation import (
    MappingStrategy,
    expected_reuse,
    map_cores,
    neuron_assignment,
    reuse_counts,
)
from repro.core.analyses import (
    hotspot_consecutive_periods,
    max_memory_requirement_bytes,
    max_path_length,
    memory_per_core_bytes,
    state_transitions,
    state_transitions_closed_form,
)
from repro.core.onoc_model import FCNNWorkload, ONoCConfig, optimal_cores
from repro.configs.nn_benchmarks import onoc_config, workload

sizes_st = st.lists(st.integers(8, 400), min_size=3, max_size=6).map(
    lambda mid: [50] + mid + [10])
cfg_st = st.builds(ONoCConfig, m=st.sampled_from([100, 333, 1000]),
                   lambda_max=st.sampled_from([8, 64]))


def _mk(sizes, cfg, strat):
    w = FCNNWorkload(sizes, batch_size=2)
    mp = map_cores(w, cfg, strat)
    return w, mp


@given(sizes_st, cfg_st,
       st.sampled_from(list(MappingStrategy)))
def test_window_sizes_match_allocation(sizes, cfg, strat):
    w, mp = _mk(sizes, cfg, strat)
    stars = optimal_cores(w, cfg)
    for i, m_i in enumerate(stars, start=1):
        assert len(mp.window(i)) == m_i
        assert all(0 <= c < cfg.m for c in mp.window(i))
        # Eq. (11): BP window is the FP window
        assert mp.window(i) == mp.window(2 * w.l - i + 1)


@given(sizes_st, cfg_st)
def test_fm_hotspot_is_2l(sizes, cfg):
    """Theorem 2: FM keeps core 0 busy for all 2l periods."""
    w, mp = _mk(sizes, cfg, MappingStrategy.FM)
    assert hotspot_consecutive_periods(mp) == 2 * w.l


@given(sizes_st, cfg_st)
def test_rrm_hotspot_bound(sizes, cfg):
    """Theorem 2: RRM <= 2 consecutive periods when adjacent periods fit
    in one ring round."""
    w, mp = _mk(sizes, cfg, MappingStrategy.RRM)
    ms = mp.cores_per_period
    if all(ms[i] + ms[i + 1] <= cfg.m for i in range(len(ms) - 1)):
        assert hotspot_consecutive_periods(mp) <= 2


@given(sizes_st, cfg_st)
def test_orrm_hotspot_bound(sizes, cfg):
    """Theorem 2 / Lemma 2: ORRM <= 4 consecutive periods under the
    one-round condition."""
    w, mp = _mk(sizes, cfg, MappingStrategy.ORRM)
    ms, r = mp.cores_per_period, mp.reuse
    if all(ms[i] + ms[i + 1] - r[i + 1] <= cfg.m for i in range(len(ms) - 1)):
        assert hotspot_consecutive_periods(mp) <= 4


@given(sizes_st, cfg_st)
def test_reuse_counts_eq17(sizes, cfg):
    w = FCNNWorkload(sizes, batch_size=1)
    ms = optimal_cores(w, cfg)
    r = reuse_counts(ms, cfg.m)
    er = expected_reuse(ms, cfg.m)
    assert r[0] == 0
    for i in range(1, len(ms)):
        assert r[i] <= round(er)
        assert r[i] <= ms[i]
        assert r[i] <= ms[i - 1] - r[i - 1]
        assert r[i] >= 0
    if sum(ms) <= cfg.m:
        assert all(x == 0 for x in r)      # Eq. (16) first branch


@given(sizes_st, cfg_st)
def test_orrm_overlap_matches_reuse(sizes, cfg):
    w, mp = _mk(sizes, cfg, MappingStrategy.ORRM)
    for i in range(1, w.l):
        overlap = set(mp.windows[i - 1]) & set(mp.windows[i])
        # planned reuse r_{i+1} cores are shared between period i and i+1
        # (wrap-around can only add overlap)
        assert len(overlap) >= mp.reuse[i]


@given(sizes_st, cfg_st, st.sampled_from(list(MappingStrategy)))
def test_neuron_assignment_balanced(sizes, cfg, strat):
    """Algorithm 1 lines 3/8: even mapping — per-core neuron counts in a
    window differ by at most 1."""
    w, mp = _mk(sizes, cfg, strat)
    asg = neuron_assignment(w, mp)
    for layer, cores in asg.items():
        counts = np.bincount(cores, minlength=cfg.m)
        active = counts[list(set(mp.windows[layer - 1]))]
        assert active.max() - active.min() <= 1
        assert counts.sum() == w.n(layer)


@given(sizes_st, cfg_st)
def test_fm_state_transitions_closed_form(sizes, cfg):
    """Table 1's FM formula is exact."""
    w, mp = _mk(sizes, cfg, MappingStrategy.FM)
    assert state_transitions(mp) == state_transitions_closed_form(mp)


@given(sizes_st, cfg_st)
def test_state_transition_ranking(sizes, cfg):
    """Table 1 ranking: FM <= ORRM <= RRM (exact counts)."""
    w = FCNNWorkload(sizes, batch_size=1)
    t = {s: state_transitions(map_cores(w, cfg, s))
         for s in MappingStrategy}
    assert t[MappingStrategy.FM] <= t[MappingStrategy.ORRM]
    assert t[MappingStrategy.ORRM] <= t[MappingStrategy.RRM]


@given(sizes_st, cfg_st)
def test_memory_ranking(sizes, cfg):
    """Table 3 ranking: RRM <= ORRM <= FM for worst-core memory, under the
    one-round condition."""
    w = FCNNWorkload(sizes, batch_size=2)
    ms = optimal_cores(w, cfg)
    mems = {}
    for s in MappingStrategy:
        mp = map_cores(w, cfg, s, ms)
        mems[s] = max_memory_requirement_bytes(w, mp)
    if sum(ms) <= cfg.m:
        assert mems[MappingStrategy.RRM] <= mems[MappingStrategy.FM] + 1e-9
        assert mems[MappingStrategy.ORRM] <= mems[MappingStrategy.FM] + 1e-9


@given(sizes_st, cfg_st)
def test_memory_conservation(sizes, cfg):
    """Total SRAM demand is strategy-independent (same neurons stored)."""
    w = FCNNWorkload(sizes, batch_size=2)
    totals = {
        s: memory_per_core_bytes(w, map_cores(w, cfg, s)).sum()
        for s in MappingStrategy
    }
    vals = list(totals.values())
    assert all(abs(v - vals[0]) < 1e-6 for v in vals)


@given(sizes_st, cfg_st)
def test_path_length_ranking(sizes, cfg):
    """Table 2 ranking: FM has the shortest max path, under one-round
    placement."""
    w = FCNNWorkload(sizes, batch_size=1)
    ms = optimal_cores(w, cfg)
    if sum(ms) > cfg.m:
        return  # wrap-around voids the closed-form ordering
    paths = {s: max_path_length(map_cores(w, cfg, s, ms))
             for s in MappingStrategy}
    assert paths[MappingStrategy.FM] <= paths[MappingStrategy.RRM]
    assert paths[MappingStrategy.FM] <= paths[MappingStrategy.ORRM]


# --------------------------------------------------------- paper §4 pinned
# Exact values for the paper benchmark FCNNs on the 1000-core ring, so the
# period-program compiler's transition costs (exec/program.py prices every
# SEND from these mappings) rest on tested ground.  Any change to
# Eqs. 16-18, Algorithm 1, or the window layout moves these numbers.

def _paper(nn, batch=64):
    w = workload(nn, batch_size=batch)
    cfg = onoc_config(lambda_max=64)
    return w, cfg, optimal_cores(w, cfg)


def test_pinned_reuse_nn1():
    """NN1 (784-1000-500-10): E[r] = (1510-1000)/2 = 255, Eq. 17 chain
    r = [0, 255, 10] (r_3 capped by m_3* = 10)."""
    _, cfg, ms = _paper("NN1")
    assert ms == [1000, 500, 10]
    assert expected_reuse(ms, cfg.m) == 255.0
    assert reuse_counts(ms, cfg.m) == [0, 255, 10]


def test_pinned_reuse_nn2():
    """NN2 (784-1500-784-1000-500-10): E[r] = (3294-1000)/4 = 573.5,
    r = [0, 574, 210, 500, 0] — r_3 capped by m_2*-r_2 = 784-574 = 210,
    r_5 = min(574, m_4*-r_4 = 0, 10) = 0."""
    _, cfg, ms = _paper("NN2")
    assert ms == [1000, 784, 1000, 500, 10]
    assert expected_reuse(ms, cfg.m) == 573.5
    assert reuse_counts(ms, cfg.m) == [0, 574, 210, 500, 0]


def test_pinned_strategy_tradeoffs_nn2():
    """The paper's §4 comparison (Tables 1-3) on NN2: FM minimizes state
    transitions but maximizes hotspot and per-core memory; RRM minimizes
    hotspot; ORRM matches RRM's hotspot at the lowest memory."""
    w, cfg, ms = _paper("NN2")
    stats = {}
    for s in MappingStrategy:
        mp = map_cores(w, cfg, s, ms)
        stats[s] = (hotspot_consecutive_periods(mp), state_transitions(mp),
                    max_memory_requirement_bytes(w, mp))
    assert stats[MappingStrategy.FM] == (2 * w.l, 4844, 4116480.0)
    assert stats[MappingStrategy.RRM] == (4, 4884, 3731456.0)
    assert stats[MappingStrategy.ORRM] == (4, 4884, 3347456.0)
    # the trade-off triangle the compiler's strategy choice navigates
    hot, st, mem = zip(*(stats[s] for s in MappingStrategy))
    assert min(st) == stats[MappingStrategy.FM][1]          # FM: fewest moves
    assert min(hot) == stats[MappingStrategy.RRM][0]        # RRM: coolest
    assert min(mem) == stats[MappingStrategy.ORRM][2]       # ORRM: leanest


def test_pinned_fm_equals_orrm_cost_when_ring_saturated():
    """NN1's first period uses the whole ring (m_1* = m = 1000), so ORRM's
    planned reuse is forced maximal and its costs degenerate to FM's
    (windows still rotate, but transitions / hotspot / memory coincide) —
    the compiler prices both strategies identically on NN1."""
    w, cfg, ms = _paper("NN1")
    fm = map_cores(w, cfg, MappingStrategy.FM, ms)
    orrm = map_cores(w, cfg, MappingStrategy.ORRM, ms)
    assert state_transitions(fm) == state_transitions(orrm) == 3980
    assert (hotspot_consecutive_periods(fm)
            == hotspot_consecutive_periods(orrm) == 2 * w.l)
    assert (max_memory_requirement_bytes(w, fm)
            == max_memory_requirement_bytes(w, orrm) == 1757184.0)


def test_pinned_closed_form_transitions_nn_sweep():
    """Table 1 FM closed form holds exactly on every paper benchmark."""
    for nn in ("NN1", "NN2", "NN3"):
        w, cfg, ms = _paper(nn)
        mp = map_cores(w, cfg, MappingStrategy.FM, ms)
        assert state_transitions(mp) == state_transitions_closed_form(mp)
