"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes and no NaNs — plus
prefill/decode consistency against the full forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, smoke_config
from repro.models.api import get_model

KEY = jax.random.PRNGKey(0)


def _lm_batch(cfg, b=2, s=16):
    if cfg.family in ("ssm", "hybrid"):
        s = max(s, cfg.ssm_chunk)
        s = (s // cfg.ssm_chunk) * cfg.ssm_chunk
    tok = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    return {"tokens": tok, "labels": tok}, b, s


def _batch_for(cfg, b=2, s=16):
    if cfg.family == "vlm":
        emb = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        pos3 = jnp.broadcast_to(pos, (3, b, s))
        lab = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        return {"embeds": emb, "positions": pos3, "labels": lab}, b, s
    if cfg.family == "encdec":
        enc = jax.random.normal(KEY, (b, s, cfg.d_model), jnp.float32)
        dec = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
        return {"enc_embeds": enc, "dec_tokens": dec, "labels": dec}, b, s
    return _lm_batch(cfg, b, s)


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch, b, s = _batch_for(cfg)

    logits = model.forward(params, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_param_axes_structure(arch):
    """Every param leaf has a same-rank logical-axes tuple."""
    cfg = smoke_config(arch)
    model = get_model(cfg)
    spec = jax.eval_shape(model.init, KEY)
    axes = model.param_axes()
    spec_leaves = jax.tree_util.tree_flatten(spec)[0]
    is_ax = lambda x: x is None or (isinstance(x, tuple) and all(  # noqa: E731
        i is None or isinstance(i, str) for i in x))
    axes_leaves = jax.tree_util.tree_flatten(axes, is_leaf=is_ax)[0]
    assert len(spec_leaves) == len(axes_leaves)
    for sp, ax in zip(spec_leaves, axes_leaves):
        if ax is not None:
            assert len(ax) == len(sp.shape), f"{arch}: {ax} vs {sp.shape}"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_prefill_decode_matches_forward(arch):
    cfg = smoke_config(arch)
    if cfg.family == "moe":
        # capacity dropping is grouping-dependent; disable drops for the
        # consistency check
        cfg = cfg.replace(capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    params = model.init(KEY)
    batch, b, s = _batch_for(cfg)
    max_len = s + 4

    kw = {"enc_len": s} if cfg.family == "encdec" else {}
    logits, cache = model.prefill(params, batch, max_len)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, {"tokens": nxt})
    assert bool(jnp.all(cache2["len"] == cache["len"] + 1))

    # extend the original sequence by the decoded token; the full forward
    # at position s must match the decode-step logits
    if cfg.family == "vlm":
        emb_tok = jnp.take(params["embedding"]["w"], nxt, axis=0)
        ext = dict(batch)
        ext["embeds"] = jnp.concatenate([batch["embeds"], emb_tok], axis=1)
        pos = jnp.broadcast_to(jnp.arange(s + 1, dtype=jnp.int32), (b, s + 1))
        ext["positions"] = jnp.broadcast_to(pos, (3, b, s + 1))
        full = model.forward(params, ext)
    elif cfg.family == "encdec":
        ext = dict(batch)
        ext["dec_tokens"] = jnp.concatenate([batch["dec_tokens"], nxt], 1)
        full = model.forward(params, ext)
    else:
        toks = jnp.concatenate([batch["tokens"], nxt], axis=1)
        if cfg.family in ("ssm", "hybrid"):
            pad = (-toks.shape[1]) % cfg.ssm_chunk
            toks = jnp.pad(toks, ((0, 0), (0, pad)))
        full = model.forward(params, {"tokens": toks})
    err = float(jnp.max(jnp.abs(full[:, s, :] - logits2[:, 0, :])))
    assert err < 2e-4, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_input_specs_cover_shapes(arch):
    from repro.configs import SHAPES, shape_cells, smoke_config

    cfg = smoke_config(arch)
    model = get_model(cfg)
    for shape_name, runnable, _ in shape_cells(cfg):
        if not runnable:
            continue
        specs = model.input_specs(SHAPES[shape_name])
        assert specs, f"{arch}/{shape_name} has empty input specs"
        for k, v in specs.items():
            assert v.shape[0] in (SHAPES[shape_name].global_batch, 3), k
