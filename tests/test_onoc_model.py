"""Properties of the paper's analytical model (Section 3, Lemma 1)."""

import math

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    brute_force_optimal_cores,
    comm_time,
    compute_time,
    epoch_time,
    optimal_cores,
    optimal_epoch_time,
    prediction_error,
    theta,
)
from repro.configs.nn_benchmarks import NN_BENCHMARKS

sizes_st = st.lists(st.integers(4, 600), min_size=3, max_size=7).map(
    lambda mid: [97] + mid + [10])
cfg_st = st.builds(
    ONoCConfig,
    m=st.sampled_from([64, 250, 1000]),
    lambda_max=st.sampled_from([4, 8, 64]),
)
batch_st = st.sampled_from([1, 8, 32])


@given(sizes_st, cfg_st, batch_st)
def test_lemma1_satisfies_constraints(sizes, cfg, bs):
    w = FCNNWorkload(sizes, batch_size=bs)
    stars = optimal_cores(w, cfg)
    for i, m in enumerate(stars, start=1):
        assert 1 <= m <= cfg.phi * cfg.m          # Eq. (9)
        assert m <= w.n(i)                         # Eq. (10)


@given(sizes_st, cfg_st, batch_st, st.randoms())
def test_optimal_beats_random_allocations(sizes, cfg, bs, rng):
    w = FCNNWorkload(sizes, batch_size=bs)
    t_opt, stars, _ = optimal_epoch_time(w, cfg, refine_plateau=True)
    t_sim, _ = epoch_time(w, cfg, brute_force_optimal_cores(w, cfg))
    # the brute-force optimum lower-bounds every allocation incl. Lemma 1's
    assert t_sim <= t_opt * (1 + 1e-9)
    for _ in range(3):
        cand = [rng.randint(1, min(int(cfg.phi * cfg.m), w.n(i)))
                for i in range(1, w.l + 1)]
        t_rand, _ = epoch_time(w, cfg, cand)
        assert t_sim <= t_rand * (1 + 1e-9)


@given(sizes_st, cfg_st)
def test_theta_formula(sizes, cfg):
    w = FCNNWorkload(sizes, batch_size=1)
    for i in range(1, w.l + 1):
        n_i, n_prev = w.n(i), w.n(i - 1)
        beta = w.beta(2 * w.l - i + 1)
        expected = n_i * cfg.lambda_max * (beta * (n_prev + 1) + w.alpha(i))
        assert math.isclose(theta(w, cfg, i), expected)


@given(sizes_st, cfg_st, batch_st)
def test_comm_time_zero_periods(sizes, cfg, bs):
    """Eq. (6): no comm in periods 1, l and 2l."""
    w = FCNNWorkload(sizes, batch_size=bs)
    l = w.l
    for i in (1, l, 2 * l):
        assert comm_time(w, cfg, i, 4) == 0.0


@given(sizes_st, cfg_st, batch_st)
def test_compute_time_monotone_in_cores(sizes, cfg, bs):
    w = FCNNWorkload(sizes, batch_size=bs)
    for i in (1, w.l):
        ts = [compute_time(w, cfg, i, m) for m in (1, 2, 4, 8)]
        assert all(a >= b - 1e-15 for a, b in zip(ts, ts[1:]))


@pytest.mark.parametrize("name", sorted(NN_BENCHMARKS))
def test_nn_benchmark_prediction_error(name):
    """Table 7 analogue: plateau-aware APE and APD stay small with the
    closed-form refinement."""
    apes, apds = [], []
    for bs in (1, 32):
        for lam in (8, 64):
            w = FCNNWorkload(NN_BENCHMARKS[name], batch_size=bs)
            cfg = ONoCConfig(lambda_max=lam)
            _, plateau, apd = prediction_error(w, cfg, refine_plateau=True)
            apes.append(plateau)
            apds.append(apd)
    assert float(np.mean(apes)) <= 0.023   # the paper's 2.3% bound
    assert float(np.mean(apds)) <= 0.05    # the paper's APD bound


def test_epoch_time_period_structure():
    w = FCNNWorkload([784, 100, 10], batch_size=4)
    cfg = ONoCConfig(m=64, lambda_max=8)
    t, periods = epoch_time(w, cfg, [32, 10])
    assert len(periods) == 2 * w.l
    # Eq. (11): BP period 2l-i+1 reuses FP period i's cores
    for i in range(1, w.l + 1):
        assert periods[i - 1].m == periods[2 * w.l - i].m
    assert t == pytest.approx(sum(p.total_s for p in periods))


def test_invalid_workloads_rejected():
    with pytest.raises(ValueError):
        FCNNWorkload([10])
    with pytest.raises(ValueError):
        FCNNWorkload([10, 0, 5])
    with pytest.raises(ValueError):
        FCNNWorkload([10, 5], batch_size=0)
    w = FCNNWorkload([784, 100, 10])
    cfg = ONoCConfig(m=64)
    with pytest.raises(ValueError):
        epoch_time(w, cfg, [100, 10])  # exceeds phi*m
