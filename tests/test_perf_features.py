"""Beyond-paper performance features: fused CE, one-hot embedding, flash
custom-VJP attention, scatter cache writes, dynamic rule/dtype scopes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.layers as L
from repro.configs import smoke_config
from repro.models.api import get_model
from repro.parallel.sharding import AxisRules, active_rules, use_rules

RNG = np.random.default_rng(11)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# ---------------------------------------------------------------- fused CE

def test_fused_ce_matches_plain():
    B, S, D, V = 2, 32, 16, 64
    emb = {"w": _arr((V, D))}
    h = _arr((B, S, D))
    lab = jnp.asarray(RNG.integers(0, V, size=(B, S)), jnp.int32)
    a = L.cross_entropy_loss(L.unembed(emb, h), lab)
    b = L.fused_unembed_ce(emb, h, lab, chunk=8)
    assert float(jnp.abs(a - b)) < 1e-5
    ga = jax.grad(lambda hh: L.cross_entropy_loss(L.unembed(emb, hh), lab))(h)
    gb = jax.grad(lambda hh: L.fused_unembed_ce(emb, hh, lab, chunk=8))(h)
    np.testing.assert_allclose(ga, gb, atol=1e-6)


def test_fused_ce_non_divisible_falls_back():
    emb = {"w": _arr((64, 16))}
    h = _arr((2, 30, 16))   # 30 % 512 != 0
    lab = jnp.asarray(RNG.integers(0, 64, size=(2, 30)), jnp.int32)
    a = L.cross_entropy_loss(L.unembed(emb, h), lab)
    b = L.fused_unembed_ce(emb, h, lab)
    assert float(jnp.abs(a - b)) < 1e-5


def test_fused_ce_in_model_loss():
    cfg = smoke_config("qwen3-14b")
    m_plain = get_model(cfg)
    m_fused = get_model(cfg.replace(fused_ce=True))
    params = m_plain.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    a = m_plain.loss_fn(params, batch)
    b = m_fused.loss_fn(params, batch)
    assert float(jnp.abs(a - b)) < 1e-4


# --------------------------------------------------------- one-hot embedding

@pytest.mark.parametrize("length", [16, 24])
def test_onehot_embed_matches_gather(length):
    p = {"w": _arr((64, 8))}
    tok = jnp.asarray(RNG.integers(0, 64, size=(2, length)), jnp.int32)
    a = L.embed(p, tok, onehot=False)
    b = L.embed(p, tok, onehot=True, chunk=8)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_onehot_embed_in_model():
    cfg = smoke_config("granite-3-2b")
    m = get_model(cfg)
    m_oh = get_model(cfg.replace(embed_onehot=True))
    params = m.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    a = m.forward(params, {"tokens": tok})
    b = m_oh.forward(params, {"tokens": tok})
    np.testing.assert_allclose(a, b, atol=1e-4)


# ------------------------------------------------------ flash VJP attention

@pytest.mark.parametrize("B,Lq,H,KV,D,chunk", [
    (2, 64, 8, 4, 16, 16),
    (1, 32, 4, 4, 8, 8),
    (2, 48, 6, 2, 16, 16),
])
def test_flash_vjp_matches_dense(B, Lq, H, KV, D, chunk):
    q, k, v = _arr((B, Lq, H, D)), _arr((B, Lq, KV, D)), _arr((B, Lq, KV, D))
    idx = jnp.arange(Lq)
    mask = (idx[None, :] <= idx[:, None])[None, None, None]
    ref = L._sdpa(q, k, v, mask)
    out = L._sdpa_chunked_causal(q, k, v, chunk, 1)
    np.testing.assert_allclose(out, ref, atol=2e-5)
    f_ref = lambda *a: (L._sdpa(*a, mask) ** 2).sum()        # noqa: E731
    f_new = lambda *a: (L._sdpa_chunked_causal(*a, chunk, 1) ** 2).sum()  # noqa: E731
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_new):
        np.testing.assert_allclose(a, b, atol=5e-5)


def test_flash_vjp_bf16():
    q = _arr((1, 32, 4, 16), jnp.bfloat16)
    k = _arr((1, 32, 2, 16), jnp.bfloat16)
    v = _arr((1, 32, 2, 16), jnp.bfloat16)
    out = L._sdpa_chunked_causal(q, k, v, 8, 1)
    idx = jnp.arange(32)
    mask = (idx[None, :] <= idx[:, None])[None, None, None]
    ref = L._sdpa(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


# -------------------------------------------------------- scatter cache write

def test_scatter_cache_write_positions():
    cfg = smoke_config("granite-3-2b")
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    logits, cache = m.prefill(params, {"tokens": tok}, 12)
    k_before = np.asarray(cache["k"])
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    _, cache2 = m.decode_step(params, cache, {"tokens": nxt})
    k_after = np.asarray(cache2["k"])
    # only slot 8 changed; slots 0..7 and 9..11 untouched
    np.testing.assert_array_equal(k_before[:, :, :8], k_after[:, :, :8])
    np.testing.assert_array_equal(k_before[:, :, 9:], k_after[:, :, 9:])
    assert np.abs(k_after[:, :, 8]).sum() > 0


# ----------------------------------------------------------- dynamic scopes

def test_use_rules_scope():
    base = active_rules()
    override = AxisRules().override(activation_batch=None)
    with use_rules(override):
        assert active_rules() is override
        with use_rules(base):
            assert active_rules() is base
        assert active_rules() is override
    assert active_rules() is base


def test_use_accum_dtype_scope():
    assert L.pet() == jnp.float32
    with L.use_accum_dtype("bfloat16"):
        assert L.pet() == jnp.bfloat16
    assert L.pet() == jnp.float32


def test_bf16_accum_model_still_close():
    cfg = smoke_config("granite-3-2b")
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    a = m.loss_fn(params, {"tokens": tok, "labels": tok})
    with L.use_accum_dtype("bfloat16"):
        b = m.loss_fn(params, {"tokens": tok, "labels": tok})
    assert abs(float(a) - float(b)) / float(a) < 0.05
