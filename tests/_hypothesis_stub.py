"""Minimal, deterministic stand-in for ``hypothesis`` used when the real
package is not installed (the CI image bakes in only the runtime deps).

conftest.py registers this module in ``sys.modules`` as ``hypothesis`` /
``hypothesis.strategies`` *only* when the real library is absent, so the
property-test modules (``from hypothesis import given, strategies as st``)
keep collecting and running instead of dying with ModuleNotFoundError.

Only the API surface this repo's tests use is implemented:

  st.integers(a, b) . st.sampled_from(xs) . st.lists(s, min_size, max_size)
  st.builds(f, **kw) . st.floats(a, b) . st.booleans() . st.tuples(*ss)
  st.randoms() . strategy.map(f) . @given(...) . settings profiles

Semantics: ``@given`` reruns the test ``MAX_EXAMPLES`` times with values
drawn from a per-test seeded ``random.Random`` — deterministic across runs
(seeded from the test name), no shrinking, no database.  Install the real
``hypothesis`` (see requirements-dev.txt) for full property testing.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib

MAX_EXAMPLES = 25


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred, _tries: int = 100):
        def draw(rng):
            for _ in range(_tries):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate never satisfied")
        return SearchStrategy(draw)


def integers(min_value, max_value):
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def booleans():
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements):
    elements = list(elements)
    return SearchStrategy(lambda rng: elements[rng.randrange(len(elements))])


def lists(elements, min_size=0, max_size=10):
    def draw(rng):
        n = rng.randint(min_size, max_size)
        return [elements.draw(rng) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies):
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strategies))


def builds(target, *arg_strategies, **kw_strategies):
    def draw(rng):
        args = [s.draw(rng) for s in arg_strategies]
        kwargs = {k: s.draw(rng) for k, s in kw_strategies.items()}
        return target(*args, **kwargs)
    return SearchStrategy(draw)


def randoms(**_kw):
    return SearchStrategy(lambda rng: random.Random(rng.getrandbits(64)))


def just(value):
    return SearchStrategy(lambda rng: value)


def one_of(*strategies):
    return SearchStrategy(
        lambda rng: strategies[rng.randrange(len(strategies))].draw(rng))


def given(*strategies, **kw_strategies):
    def decorate(test):
        @functools.wraps(test)
        def wrapper(*args, **kwargs):
            del args, kwargs  # drawn values only; no pytest fixtures
            seed = zlib.adler32(test.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(settings._max_examples):
                drawn = [s.draw(rng) for s in strategies]
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    test(*drawn, **drawn_kw)
                except _Unsatisfied:
                    continue
        # Hide the wrapped test's parameters from pytest's fixture
        # resolution — all arguments are drawn from the strategies.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return decorate


class settings:
    """Profile registry — only max_examples/deadline are honoured."""

    _profiles: dict[str, dict] = {}
    _max_examples = MAX_EXAMPLES

    def __init__(self, max_examples=MAX_EXAMPLES, deadline=None, **_kw):
        self.max_examples = max_examples

    def __call__(self, test):  # used as @settings(...) decorator
        return test

    @classmethod
    def register_profile(cls, name, max_examples=MAX_EXAMPLES, **kw):
        cls._profiles[name] = {"max_examples": max_examples, **kw}

    @classmethod
    def load_profile(cls, name):
        cls._max_examples = cls._profiles.get(name, {}).get(
            "max_examples", MAX_EXAMPLES)


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


HealthCheck = type("HealthCheck", (), {"all": staticmethod(lambda: [])})
