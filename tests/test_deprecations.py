"""Warn-once deprecation shims (ISSUE 9 satellite): each deprecated
entry point emits its DeprecationWarning exactly once per process, and
``repro.deprecation.reset`` re-arms it."""

import warnings

import pytest

from repro import deprecation
from repro.configs.nn_benchmarks import onoc_config, workload
from repro.exec.program import compile_fcnn_program
from repro.launch.mesh import make_test_mesh
from repro.optim.optimizers import adamw

N_DEV = 8
W = workload("NN1", batch_size=8)
CFG = onoc_config(lambda_max=64)


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(N_DEV)


@pytest.fixture(scope="module")
def prog():
    return compile_fcnn_program(W, CFG, N_DEV, "orrm")


def _call_runtime_shim(prog, mesh):
    from repro.exec.runtime import build_train_step
    build_train_step(prog, mesh, adamw(1e-3))  # lint: allow-deprecated


def _call_steps_shim(prog, mesh):
    from repro.launch.steps import build_fcnn_program_step
    build_fcnn_program_step(prog, mesh)  # lint: allow-deprecated


@pytest.mark.parametrize("call,key", [
    (_call_runtime_shim, "exec.runtime.build_train_step"),
    (_call_steps_shim, "launch.steps.build_fcnn_program_step"),
], ids=["exec.runtime.build_train_step",
        "launch.steps.build_fcnn_program_step"])
def test_shim_warns_exactly_once(call, key, prog, mesh):
    deprecation.reset(key)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        call(prog, mesh)
    # second call in the same process: armed key already spent, silent
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        call(prog, mesh)
    assert [w for w in caught
            if issubclass(w.category, DeprecationWarning)] == []


def test_launch_serve_shim_warns_once():
    """repro.launch.serve.SlotManager moved to repro.serve.scheduler; the
    old attribute is a PEP 562 warn-once shim resolving to the same class
    (ISSUE 10: the prototype was promoted to the serve subsystem)."""
    import repro.launch.serve as launch_serve
    from repro.serve.scheduler import SlotManager

    deprecation.reset("launch.serve.SlotManager")
    with pytest.warns(DeprecationWarning, match="repro.serve"):
        cls = launch_serve.SlotManager  # lint: allow-deprecated
    assert cls is SlotManager
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        cls = launch_serve.SlotManager  # lint: allow-deprecated
    assert [w for w in caught
            if issubclass(w.category, DeprecationWarning)] == []
    with pytest.raises(AttributeError):
        launch_serve.NoSuchThing


def test_warn_once_per_key_and_reset():
    deprecation.reset()
    with pytest.warns(DeprecationWarning, match="gone soon"):
        deprecation.warn_deprecated("k1", "gone soon", stacklevel=2)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        deprecation.warn_deprecated("k1", "gone soon", stacklevel=2)
    assert caught == []
    # a different key is independent
    with pytest.warns(DeprecationWarning):
        deprecation.warn_deprecated("k2", "also gone", stacklevel=2)
    # reset(key) re-arms just that key
    deprecation.reset("k1")
    with pytest.warns(DeprecationWarning):
        deprecation.warn_deprecated("k1", "gone soon", stacklevel=2)
