"""Static program verification (ISSUE 7: exec/validate.py) — every
hand-corrupted program must be rejected with a precise error, every
compiled program must pass."""

import dataclasses

import pytest

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.allocation import MappingStrategy
from repro.core.simulator import ENoCBackend
from repro.exec.program import (
    Instruction,
    Opcode,
    compile_fcnn_program,
)
from repro.exec.validate import ProgramValidationError, validate_program

N_DEV = 8
W = workload("NN1", batch_size=8)
CFG = onoc_config(lambda_max=64)


@pytest.fixture(scope="module")
def prog():
    return compile_fcnn_program(W, CFG, N_DEV, "orrm")


def _with_instrs(prog, instrs):
    return dataclasses.replace(prog, instructions=tuple(instrs))


@pytest.mark.parametrize("strategy", list(MappingStrategy))
@pytest.mark.parametrize("backend", [None, ENoCBackend()])
def test_compiled_programs_validate(strategy, backend):
    """compile_* validates internally; re-validating externally (with the
    full cost contract) must also pass for every strategy and backend."""
    p = compile_fcnn_program(W, CFG, N_DEV, strategy, backend=backend)
    validate_program(p, W, CFG, backend=backend)


def test_rejects_dangling_recv(prog):
    instrs = [i for i in prog.instructions
              if not (i.opcode is Opcode.SEND and i.period == 2)]
    with pytest.raises(ProgramValidationError,
                       match="dangling RECV at period 2: no matching SEND"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_dangling_send(prog):
    instrs = [i for i in prog.instructions
              if not (i.opcode is Opcode.RECV and i.period == 2)]
    with pytest.raises(ProgramValidationError,
                       match="dangling SEND at period 2"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_out_of_mesh_window(prog):
    instrs = list(prog.instructions)
    idx = next(k for k, i in enumerate(instrs) if i.opcode is Opcode.FREE)
    bad = dataclasses.replace(
        instrs[idx], devices=(N_DEV + 91,) + instrs[idx].devices[1:])
    instrs[idx] = bad
    with pytest.raises(ProgramValidationError,
                       match=r"outside the 8-device mesh"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_free_before_last_use(prog):
    runs = {i.period: i for i in prog.instructions if i.opcode is Opcode.RUN}
    p = next(p for p in sorted(runs) if p < 2 * W.l
             and set(runs[p].devices) & set(runs[p + 1].devices))
    dev = min(set(runs[p].devices) & set(runs[p + 1].devices))
    instrs = []
    for i in prog.instructions:
        instrs.append(i)
        if i.opcode is Opcode.RUN and i.period == p:
            instrs.append(Instruction.FREE(period=p, released=(dev,)))
    with pytest.raises(ProgramValidationError,
                       match="freed before last use"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_non_divisor_degree(prog):
    instrs = list(prog.instructions)
    idx = next(k for k, i in enumerate(instrs)
               if i.opcode is Opcode.RUN and i.degree > 1)
    r = instrs[idx]
    instrs[idx] = dataclasses.replace(r, degree=3, devices=(0, 1, 2))
    with pytest.raises(ProgramValidationError,
                       match="degree 3 does not divide the device count 8"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_residency_leak(prog):
    """A device leaving the window with its FREE dropped is a leak."""
    drop = next(i for i in prog.instructions
                if i.opcode is Opcode.FREE and i.period < 2 * W.l)
    instrs = [i for i in prog.instructions if i is not drop]
    with pytest.raises(ProgramValidationError, match="residency leak"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_cost_contract_violation(prog):
    instrs = list(prog.instructions)
    idx = next(k for k, i in enumerate(instrs) if i.opcode is Opcode.RUN)
    instrs[idx] = dataclasses.replace(instrs[idx],
                                      cost_s=instrs[idx].cost_s * 2 + 1)
    bad = _with_instrs(prog, instrs)
    validate_program(bad)        # structure-only: costs not checked
    with pytest.raises(ProgramValidationError, match="simulator contract"):
        validate_program(bad, W, CFG)


def test_rejects_missing_run(prog):
    instrs = [i for i in prog.instructions
              if not (i.opcode is Opcode.RUN and i.period == 2)]
    with pytest.raises(ProgramValidationError, match="missing periods \\[2\\]"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_broken_bp_mirror(prog):
    """Eq. 11: BP windows must mirror FP windows."""
    instrs = list(prog.instructions)
    idx = next(k for k, i in enumerate(instrs)
               if i.opcode is Opcode.RUN and i.phase == "bp"
               and len(i.devices) > 1)
    r = instrs[idx]
    rotated = r.devices[1:] + r.devices[:1]
    instrs[idx] = dataclasses.replace(r, devices=rotated)
    with pytest.raises(ProgramValidationError, match="Eq. 11"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_run_on_non_resident_chunks(prog):
    """A RUN scheduled after its layer's param FREE touches freed chunks
    (ISSUE 8 acceptance: validate_program rejects it)."""
    l = W.l
    instrs = list(prog.instructions)
    pf = next(i for i in instrs if i.opcode is Opcode.FREE and i.layer == 1)
    instrs.remove(pf)
    idx = next(k for k, i in enumerate(instrs)
               if i.opcode is Opcode.RUN and i.period == 2 * l)
    instrs.insert(idx, pf)
    with pytest.raises(ProgramValidationError, match="non-resident"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_param_free_byte_mismatch(prog):
    """FREE-after-last-use now verifies *bytes*: releasing fewer bytes
    than resident leaves the ledger undrained."""
    instrs = list(prog.instructions)
    idx = next(k for k, i in enumerate(instrs)
               if i.opcode is Opcode.FREE and i.layer is not None)
    instrs[idx] = dataclasses.replace(
        instrs[idx], param_bytes=instrs[idx].param_bytes / 2)
    with pytest.raises(ProgramValidationError,
                       match="ledger would not drain"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_param_free_off_mirror_period(prog):
    """Param FREEs must sit at the layer's Eq.-11 BP mirror period (the
    chunk's last use), nowhere else."""
    l = W.l
    instrs = list(prog.instructions)
    pf = next(i for i in instrs if i.opcode is Opcode.FREE and i.layer == 2)
    instrs.remove(pf)
    instrs.append(dataclasses.replace(pf, period=2 * l))
    with pytest.raises(ProgramValidationError, match="BP mirror period"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_missing_param_free(prog):
    instrs = [i for i in prog.instructions
              if not (i.opcode is Opcode.FREE and i.layer == 1)]
    with pytest.raises(ProgramValidationError,
                       match="exactly one param FREE"):
        validate_program(_with_instrs(prog, instrs))


def test_rejects_param_bytes_geometry_mismatch(prog):
    """A self-consistent but wrong byte ledger passes structurally and is
    caught by the chunk-geometry check once workload+cfg are supplied."""
    instrs = []
    for i in prog.instructions:
        if i.layer == 1:          # FP RUN, BP RUN and param FREE of layer 1
            i = dataclasses.replace(i, param_bytes=i.param_bytes * 2)
        instrs.append(i)
    bad = _with_instrs(prog, instrs)
    validate_program(bad)         # structure-only: ledger drains, passes
    with pytest.raises(ProgramValidationError, match="chunk geometry"):
        validate_program(bad, W, CFG)


def test_v1_programs_skip_residency_ledger(prog):
    """Schema-v1 programs (PR 6) have no residency annotations; the
    ledger checks only apply from v2 on."""
    instrs = [i for i in prog.instructions
              if not (i.opcode is Opcode.FREE and i.layer is not None)]
    with pytest.raises(ProgramValidationError,
                       match="exactly one param FREE"):
        validate_program(_with_instrs(prog, instrs))
    v1 = dataclasses.replace(_with_instrs(prog, instrs), version=1)
    validate_program(v1)          # same instructions, v1: accepted


def test_compile_program_validates_by_default():
    """The compile path itself runs the verifier (validate=True default):
    sabotaging the verifier's input via a monkeypatched compile would be
    caught — here we just pin that a valid compile round-trips and that
    validate=False is required to construct broken programs (used above)."""
    p = compile_fcnn_program(W, CFG, N_DEV, "rrm")
    validate_program(p, W, CFG)
