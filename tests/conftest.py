import os
import sys

# Tests run on host CPU devices — the dry-run (and only the dry-run)
# forces 512 devices via its own XLA_FLAGS before jax init.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Force an 8-device CPU ring for the whole suite (must land before the
# first jax backend init) so the period-program executor and every
# shard_map path are tested on a real multi-device mesh without TPUs
# (launch.mesh.make_test_mesh picks these up).
_flags = os.environ.get("XLA_FLAGS", "")
if "--xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count=8 {_flags}".strip())

try:
    from hypothesis import settings
except ModuleNotFoundError:
    # The runtime image ships without hypothesis.  Install the deterministic
    # stub (tests/_hypothesis_stub.py) under both module names so the
    # property-test modules still collect and run their checks with a fixed
    # sample budget instead of erroring out the whole session.
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub as _stub

    sys.modules.setdefault("hypothesis", _stub)
    sys.modules.setdefault("hypothesis.strategies", _stub)
    _stub.strategies = _stub
    settings = _stub.settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
