import os

# Tests run on the single host CPU device — the dry-run (and only the
# dry-run) forces 512 devices via its own XLA_FLAGS before jax init.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
