"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R

RNG = np.random.default_rng(7)


def _arr(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 128, 128, 128, 256),
    (128, 1024, 256, 64, 128, 512),
    (384, 256, 384, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["sigmoid", "relu", "none"])
def test_fcnn_layer_kernel(m, k, n, bm, bn, bk, dtype, act):
    x, w, b = _arr((m, k), dtype), _arr((k, n), dtype, 0.05), _arr((n,), dtype)
    out = ops.fcnn_layer(x, w, b, act, force="pallas_interpret",
                         block_m=bm, block_n=bn, block_k=bk)
    refv = R.fcnn_layer_ref(x, w, b, act)
    tol = 5e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refv, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,s,d,bq", [
    (1, 2, 128, 32, 64),
    (2, 4, 256, 64, 128),
    (1, 1, 64, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, h, s, d, bq, causal, dtype):
    q, k, v = (_arr((b, h, s, d), dtype) for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=causal,
                              force="pallas_interpret",
                              block_q=bq, block_kv=bq)
    refv = R.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refv, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bc,q,h,p,n,bh", [
    (2, 16, 8, 8, 4, 4),
    (1, 32, 4, 16, 8, 4),
    (3, 8, 16, 8, 16, 8),
])
def test_ssd_chunk_kernel(bc, q, h, p, n, bh):
    x = _arr((bc, q, h, p), jnp.float32)
    dt_a = -jnp.abs(_arr((bc, q, h), jnp.float32)) * 0.3
    b = _arr((bc, q, h, n), jnp.float32)
    c = _arr((bc, q, h, n), jnp.float32)
    y, st, dec = ops.ssd_chunk(x, dt_a, b, c, force="pallas_interpret",
                               block_h=bh)
    y2, st2, dec2 = ops.ssd_chunk(x, dt_a, b, c, force="ref")
    np.testing.assert_allclose(y, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(st, st2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dec, dec2, rtol=1e-5, atol=1e-5)


def test_ops_dispatch_cpu_uses_ref():
    """Off-TPU the public wrappers run the oracle path."""
    x, w, b = _arr((8, 8), jnp.float32), _arr((8, 8), jnp.float32), _arr((8,), jnp.float32)
    out = ops.fcnn_layer(x, w, b)           # no force: CPU -> ref
    np.testing.assert_allclose(out, R.fcnn_layer_ref(x, w, b), rtol=1e-6)


def test_kernel_block_divisibility_error():
    x, w, b = _arr((100, 64), jnp.float32), _arr((64, 64), jnp.float32), _arr((64,), jnp.float32)
    with pytest.raises(ValueError):
        ops.fcnn_layer(x, w, b, force="pallas_interpret", block_m=64)
