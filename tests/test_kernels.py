"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes — forward values AND (for the fused fcnn
kernel) gradients through the custom VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R
from repro.kernels.fcnn_layer import (
    fcnn_layer_dgrad,
    fcnn_layer_wgrad,
    select_blocks,
)

RNG = np.random.default_rng(7)


def _arr(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 128, 128, 128, 256),
    (128, 1024, 256, 64, 128, 512),
    (384, 256, 384, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["sigmoid", "relu", "none"])
def test_fcnn_layer_kernel(m, k, n, bm, bn, bk, dtype, act):
    x, w, b = _arr((m, k), dtype), _arr((k, n), dtype, 0.05), _arr((n,), dtype)
    out = ops.fcnn_layer(x, w, b, act, force="pallas_interpret",
                         block_m=bm, block_n=bn, block_k=bk)
    refv = R.fcnn_layer_ref(x, w, b, act)
    tol = 5e-6 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refv, np.float32),
                               rtol=tol, atol=tol)


# The paper's NN benchmark layer shapes are NOT 128-divisible (784 inputs,
# 10 output classes); the kernel must pad edge tiles instead of raising.
@pytest.mark.parametrize("m,k,n", [
    (32, 784, 1000),    # NN1 layer 1
    (32, 500, 10),      # NN1 output layer
    (100, 64, 64),      # non-divisible batch
    (8, 1024, 4000),    # NN5/NN6 wide layer
    (7, 13, 5),         # everything tiny and ragged
])
@pytest.mark.parametrize("act", ["sigmoid", "relu", "tanh", "none"])
def test_fcnn_layer_kernel_nonaligned(m, k, n, act):
    x = _arr((m, k), jnp.float32)
    w = _arr((k, n), jnp.float32, 0.05)
    b = _arr((n,), jnp.float32)
    out = ops.fcnn_layer(x, w, b, act, force="pallas_interpret")
    refv = R.fcnn_layer_ref(x, w, b, act)
    np.testing.assert_allclose(np.asarray(out), np.asarray(refv),
                               rtol=5e-6, atol=5e-6)


@pytest.mark.parametrize("m,k,n", [
    (32, 784, 1000),    # NN1 layer 1 (non-128-divisible K)
    (32, 1000, 500),
    (32, 500, 10),      # 10-class output layer
    (16, 1024, 4000),   # NN5/NN6 wide layer
])
@pytest.mark.parametrize("act", ["sigmoid", "relu", "tanh", "none"])
def test_fcnn_layer_grad_matches_ref(m, k, n, act):
    """jax.grad through the Pallas custom-VJP dispatch == autodiff of the
    oracle, for x, w and b (acceptance criterion: 1e-5 fp32)."""
    x = _arr((m, k), jnp.float32)
    w = _arr((k, n), jnp.float32, 0.05)
    b = _arr((n,), jnp.float32)
    t = _arr((m, n), jnp.float32)

    def loss(p, mode):
        y = ops.fcnn_layer(p["x"], p["w"], p["b"], act, force=mode)
        return jnp.mean((y.astype(jnp.float32) - t) ** 2)

    g_pallas = jax.grad(lambda p: loss(p, "pallas_interpret"))(
        {"x": x, "w": w, "b": b})
    g_ref = jax.grad(lambda p: loss(p, "ref"))({"x": x, "w": w, "b": b})
    for name in ("x", "w", "b"):
        np.testing.assert_allclose(
            np.asarray(g_pallas[name]), np.asarray(g_ref[name]),
            rtol=1e-5, atol=1e-5, err_msg=f"d{name} act={act}")


@pytest.mark.parametrize("act", ["sigmoid", "relu", "tanh", "none"])
def test_fcnn_backward_kernels_match_oracles(act):
    """The dgrad/wgrad Pallas kernels against their ref.py oracles."""
    m, k, n = 48, 200, 75
    x = _arr((m, k), jnp.float32)
    w = _arr((k, n), jnp.float32, 0.05)
    b = _arr((n,), jnp.float32)
    dy = _arr((m, n), jnp.float32)
    y = R.fcnn_layer_ref(x, w, b, act)

    dx = fcnn_layer_dgrad(dy, y, w, act, interpret=True)
    dx_ref = R.fcnn_layer_dgrad_ref(dy, y, w, act)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=5e-6, atol=5e-6)

    dw, db = fcnn_layer_wgrad(x, dy, y, act, interpret=True)
    dw_ref, db_ref = R.fcnn_layer_wgrad_ref(x, dy, y, act)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=5e-6, atol=5e-6)
    np.testing.assert_allclose(np.asarray(db), np.asarray(db_ref),
                               rtol=5e-6, atol=5e-6)


def test_fcnn_grad_through_model_loss():
    """End-to-end: grad of the FCNN cross-entropy loss, fused vs ref."""
    from repro.models import fcnn

    sizes = [784, 64, 10]   # non-aligned input layer
    params = fcnn.init(jax.random.PRNGKey(0), sizes)
    batch = {
        "x": _arr((16, sizes[0]), jnp.float32),
        "y": jnp.asarray(RNG.integers(0, sizes[-1], size=16), jnp.int32),
    }
    g_pallas = jax.grad(
        lambda p: fcnn.loss_fn(p, batch, kernel_mode="pallas_interpret")
    )(params)
    g_ref = jax.grad(
        lambda p: fcnn.loss_fn(p, batch, kernel_mode="ref"))(params)
    flat_p, _ = jax.tree_util.tree_flatten(g_pallas)
    flat_r, _ = jax.tree_util.tree_flatten(g_ref)
    for a, b_ in zip(flat_p, flat_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------- fused softmax/xent


@pytest.mark.parametrize("b", [1, 64, 128])
@pytest.mark.parametrize("n", [10, 26])
def test_softmax_xent_matches_ref(b, n):
    """Fused loss AND its gradient vs jax.grad of the ref loss, on the
    paper's non-128-aligned class counts (10 classes, batch down to 1)."""
    logits = _arr((b, n), jnp.float32, 3.0)
    labels = jnp.asarray(RNG.integers(0, n, size=b), jnp.int32)

    loss_p = ops.softmax_xent(logits, labels, force="pallas_interpret")
    loss_r = ops.softmax_xent(logits, labels, force="ref")
    np.testing.assert_allclose(float(loss_p), float(loss_r),
                               rtol=1e-6, atol=1e-6)

    g_p = jax.grad(lambda x: ops.softmax_xent(
        x, labels, force="pallas_interpret"))(logits)
    g_r = jax.grad(lambda x: ops.softmax_xent(x, labels, force="ref"))(logits)
    np.testing.assert_allclose(np.asarray(g_p), np.asarray(g_r),
                               rtol=1e-5, atol=1e-6)


def test_softmax_xent_kernels_match_oracles():
    """The forward (nll, lse) and backward (dlogits) Pallas kernels against
    their ref.py oracles, including a non-default block override."""
    from repro.kernels.softmax_xent import (
        softmax_xent_dlogits,
        softmax_xent_fwd,
    )

    b, n = 37, 300   # ragged in both dims, several class tiles at bc=128
    logits = _arr((b, n), jnp.float32, 2.0)
    labels = jnp.asarray(RNG.integers(0, n, size=b), jnp.int32)

    nll, lse = softmax_xent_fwd(logits, labels, block_c=128, interpret=True)
    logp = jax.nn.log_softmax(np.asarray(logits, np.float32), axis=-1)
    nll_ref = -np.take_along_axis(logp, np.asarray(labels)[:, None], 1)[:, 0]
    lse_ref = np.log(np.sum(np.exp(np.asarray(logits, np.float32)), axis=-1))
    np.testing.assert_allclose(np.asarray(nll), nll_ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lse), lse_ref, rtol=1e-5, atol=1e-5)

    g = jnp.float32(0.7)
    scale = jnp.full((b,), g / b, jnp.float32)
    dl = softmax_xent_dlogits(logits, labels, lse, scale,
                              block_c=128, interpret=True)
    dl_ref = R.softmax_xent_dlogits_ref(logits, labels, g)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(dl_ref),
                               rtol=1e-5, atol=1e-6)


def test_fcnn_loss_fn_matches_prefusion_value():
    """End-to-end: fcnn.loss_fn (now dispatching the fused kernel) agrees
    with the pre-fusion jnp log-softmax + NLL loss in every mode."""
    from repro.models import fcnn

    sizes = [784, 64, 10]
    params = fcnn.init(jax.random.PRNGKey(3), sizes)
    batch = {
        "x": _arr((16, sizes[0]), jnp.float32),
        "y": jnp.asarray(RNG.integers(0, sizes[-1], size=16), jnp.int32),
    }

    def prefusion_loss(mode):
        logits = fcnn.forward(params, batch["x"], kernel_mode=mode)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return jnp.mean(
            -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0])

    for mode in ("ref", "pallas_interpret"):
        fused = float(fcnn.loss_fn(params, batch, kernel_mode=mode))
        np.testing.assert_allclose(fused, float(prefusion_loss(mode)),
                                   rtol=1e-6, atol=1e-6)


def test_select_blocks_minimizes_padding():
    (bm, bn, bk), (mp, np_, kp) = select_blocks(784, 784, 10)
    assert mp % bm == 0 and np_ % bn == 0 and kp % bk == 0
    assert mp - 784 < bm and kp - 784 < 128 + bk  # minimal edge padding
    assert np_ == 128  # 10 -> one lane tile


@pytest.mark.parametrize("b,h,s,d,bq", [
    (1, 2, 128, 32, 64),
    (2, 4, 256, 64, 128),
    (1, 1, 64, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(b, h, s, d, bq, causal, dtype):
    q, k, v = (_arr((b, h, s, d), dtype) for _ in range(3))
    out = ops.flash_attention(q, k, v, causal=causal,
                              force="pallas_interpret",
                              block_q=bq, block_kv=bq)
    refv = R.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(refv, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bc,q,h,p,n,bh", [
    (2, 16, 8, 8, 4, 4),
    (1, 32, 4, 16, 8, 4),
    (3, 8, 16, 8, 16, 8),
])
def test_ssd_chunk_kernel(bc, q, h, p, n, bh):
    x = _arr((bc, q, h, p), jnp.float32)
    dt_a = -jnp.abs(_arr((bc, q, h), jnp.float32)) * 0.3
    b = _arr((bc, q, h, n), jnp.float32)
    c = _arr((bc, q, h, n), jnp.float32)
    y, st, dec = ops.ssd_chunk(x, dt_a, b, c, force="pallas_interpret",
                               block_h=bh)
    y2, st2, dec2 = ops.ssd_chunk(x, dt_a, b, c, force="ref")
    np.testing.assert_allclose(y, y2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(st, st2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dec, dec2, rtol=1e-5, atol=1e-5)


def test_ops_dispatch_cpu_uses_ref():
    """Off-TPU the public wrappers run the oracle path."""
    x, w, b = _arr((8, 8), jnp.float32), _arr((8, 8), jnp.float32), _arr((8,), jnp.float32)
    out = ops.fcnn_layer(x, w, b)           # no force: CPU -> ref
    np.testing.assert_allclose(out, R.fcnn_layer_ref(x, w, b), rtol=1e-6)


def test_kernel_nondivisible_blocks_pad_instead_of_raising():
    """Explicit block overrides that don't divide the shape are treated as
    preferred sizes: the kernel pads edge tiles rather than raising."""
    x, w, b = _arr((100, 64), jnp.float32), _arr((64, 64), jnp.float32), _arr((64,), jnp.float32)
    out = ops.fcnn_layer(x, w, b, force="pallas_interpret", block_m=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(R.fcnn_layer_ref(x, w, b)),
                               rtol=5e-6, atol=5e-6)


def test_kernel_unknown_activation_raises():
    x, w, b = _arr((8, 8), jnp.float32), _arr((8, 8), jnp.float32), _arr((8,), jnp.float32)
    with pytest.raises(ValueError):
        ops.fcnn_layer(x, w, b, "swish", force="pallas_interpret")
