"""Executor: compiled programs running under shard_map on a multi-device
CPU mesh must reproduce the single-device fused training path (ISSUE 6
acceptance: >=4 devices, >=2 paper FCNN configs, losses/params matching
within fp tolerance)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.allocation import MappingStrategy
from repro.data import fcnn_classification_dataset
from repro.exec.program import compile_fcnn_program
from repro.exec.runtime import ProgramExecutor, build_train_step
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_test_mesh
from repro.models import fcnn
from repro.optim import adam
from repro.parallel.sharding import replicate

N_DEV = 8


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(N_DEV)


def _setup(nn, batch, strategy="orrm", n_dev=N_DEV):
    w = workload(nn, batch_size=batch)
    cfg = onoc_config(lambda_max=64)
    prog = compile_fcnn_program(w, cfg, n_dev, strategy)
    params = fcnn.init(jax.random.PRNGKey(0), w.layer_sizes)
    x, y = fcnn_classification_dataset(batch, input_dim=w.layer_sizes[0],
                                       seed=3)
    batch_d = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    return w, prog, params, batch_d


def test_make_test_mesh(mesh):
    assert mesh.devices.size == N_DEV >= 4
    assert mesh.axis_names == ("cores",)
    with pytest.raises(RuntimeError):
        make_test_mesh(len(jax.devices()) + 1)


def test_program_uses_multiple_degrees():
    """The schedule genuinely remaps: different periods run at different
    device counts on the 8-ring (NN1: 1000 -> 8, 500 -> 4, 10 -> 2)."""
    _, prog, _, _ = _setup("NN1", 8)
    assert len(set(prog.degrees)) > 1
    assert max(prog.degrees) >= 4


@pytest.mark.parametrize("nn", ["NN1", "NN2"])
def test_loss_and_grads_match_single_device(mesh, nn):
    w, prog, params, batch = _setup(nn, batch=8)
    ex = ProgramExecutor(prog, mesh, kernel_mode="ref")

    loss_1d, grads_1d = jax.value_and_grad(
        lambda p: fcnn.loss_fn(p, batch, kernel_mode="ref"))(params)
    loss_ex, grads_ex = jax.jit(jax.value_and_grad(ex.loss_fn))(
        replicate(params, mesh), batch)

    np.testing.assert_allclose(loss_ex, loss_1d, rtol=1e-6, atol=1e-7)
    for g1, g2 in zip(jax.tree.leaves(grads_1d), jax.tree.leaves(grads_ex)):
        np.testing.assert_allclose(g2, g1, rtol=1e-4, atol=1e-7)


@pytest.mark.parametrize("nn", ["NN1", "NN2"])
def test_training_matches_single_device(mesh, nn):
    """5 optimizer steps through the executor bit-track the single-device
    fused path (same init, same batches, same adam)."""
    w, prog, params0, _ = _setup(nn, batch=8)
    x, y = fcnn_classification_dataset(64, input_dim=w.layer_sizes[0],
                                       seed=7)
    opt = adam(1e-2)

    step_ex, _ = build_train_step(  # lint: allow-deprecated
        prog, mesh, opt, kernel_mode="ref")

    @jax.jit
    def step_1d(params, opt_state, batch, i):
        loss, grads = jax.value_and_grad(
            lambda p, b: fcnn.loss_fn(p, b, kernel_mode="ref"))(params, batch)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, loss

    p_ex = replicate(params0, mesh)
    p_1d = params0
    s_ex, s_1d = opt.init(p_ex), opt.init(p_1d)
    for i in range(5):
        batch = {"x": jnp.asarray(x[i * 8:(i + 1) * 8]),
                 "y": jnp.asarray(y[i * 8:(i + 1) * 8])}
        p_ex, s_ex, loss_ex = step_ex(p_ex, s_ex, batch, i)
        p_1d, s_1d, loss_1d = step_1d(p_1d, s_1d, batch, i)
        np.testing.assert_allclose(loss_ex, loss_1d, rtol=1e-5, atol=1e-6)
    # adam's 1/sqrt(v) amplifies reduction-order fp noise on near-zero
    # grads; 5e-4 absolute on O(1e-1) params after 5 steps is still a
    # training-equivalent match
    for a, b in zip(jax.tree.leaves(p_1d), jax.tree.leaves(p_ex)):
        np.testing.assert_allclose(b, a, rtol=1e-3, atol=5e-4)


def test_strategies_are_numerically_equivalent(mesh):
    """FM/RRM/ORRM place chunks on different devices but must compute the
    same function."""
    losses = []
    for strat in MappingStrategy:
        _, prog, params, batch = _setup("NN1", 8, strategy=strat)
        ex = ProgramExecutor(prog, mesh, kernel_mode="ref")
        losses.append(float(jax.jit(ex.loss_fn)(params, batch)))
    assert losses[0] == pytest.approx(losses[1], rel=1e-7)
    assert losses[0] == pytest.approx(losses[2], rel=1e-7)


def test_interpreted_pallas_kernels_under_shard_map(mesh):
    """The fused kernels themselves (interpreter mode) run per-shard inside
    the executor and agree with the oracle path."""
    sizes = [32, 16, 8, 10]
    from repro.core.onoc_model import FCNNWorkload
    w = FCNNWorkload(sizes, batch_size=4)
    prog = compile_fcnn_program(w, onoc_config(), N_DEV, "rrm")
    params = fcnn.init(jax.random.PRNGKey(1), sizes)
    x, y = fcnn_classification_dataset(4, input_dim=32, seed=5)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}

    ex_interp = ProgramExecutor(prog, mesh, kernel_mode="pallas_interpret")
    ex_ref = ProgramExecutor(prog, mesh, kernel_mode="ref")
    l_i, g_i = jax.value_and_grad(ex_interp.loss_fn)(params, batch)
    l_r, g_r = jax.value_and_grad(ex_ref.loss_fn)(params, batch)
    np.testing.assert_allclose(l_i, l_r, rtol=1e-5, atol=1e-6)
    for a, b in zip(jax.tree.leaves(g_r), jax.tree.leaves(g_i)):
        np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-6)


def test_build_fcnn_program_step(mesh):
    """launch.steps integration: the program step trains (loss decreases)
    and reports finite grad norms."""
    w, prog, _, _ = _setup("NN1", 8)
    settings = steps_lib.TrainSettings(learning_rate=1e-2)
    step, ex = steps_lib.build_fcnn_program_step(  # lint: allow-deprecated
        prog, mesh, settings, kernel_mode="ref")
    state = steps_lib.init_fcnn_program_state(prog, settings,
                                              jax.random.PRNGKey(0))
    x, y = fcnn_classification_dataset(32, input_dim=784, seed=11)
    batch = {"x": jnp.asarray(x), "y": jnp.asarray(y)}
    first = last = None
    for _ in range(6):
        state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics["grad_norm"]))
        first = float(metrics["loss"]) if first is None else first
        last = float(metrics["loss"])
    # same batch every step: the optimizer must make progress on it
    assert last < first
    assert int(state["step"]) == 6


def test_executor_validates_mesh_and_params(mesh):
    _, prog, params, batch = _setup("NN1", 8)
    with pytest.raises(ValueError):  # wrong device count
        ProgramExecutor(prog, make_test_mesh(4), kernel_mode="ref")
    ex = ProgramExecutor(prog, mesh, kernel_mode="ref")
    bad = fcnn.init(jax.random.PRNGKey(0), [784, 64, 10])
    with pytest.raises(ValueError):
        ex.loss_fn(bad, batch)
