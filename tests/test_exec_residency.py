"""Weight-sharded residency (ISSUE 8): tracker-level byte accounting on
the 8-device CPU ring, bit-for-bit equivalence of the sharded executor
with the replicated oracle, and the plan->compile->execute façade."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.exec as rexec
from repro.configs.nn_benchmarks import onoc_config, workload
from repro.data import fcnn_classification_dataset
from repro.exec.program import PeriodProgram, compile_fcnn_program
from repro.exec.residency import ResidencyTracker, replicated_model_bytes
from repro.exec.runtime import ProgramExecutor
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_test_mesh
from repro.models import fcnn
from repro.optim import adam
from repro.optim.optimizers import adamw

N_DEV = 8
CFG = onoc_config(lambda_max=64)


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh(N_DEV)


def _batch(w, batch, seed=3):
    x, y = fcnn_classification_dataset(batch, input_dim=w.layer_sizes[0],
                                       seed=seed)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


# ----------------------------------------------------------- tracker level


@pytest.mark.parametrize("nn", ["NN1", "NN2"])
@pytest.mark.parametrize("strategy", ["fm", "rrm", "orrm"])
def test_per_period_bytes_are_replicated_over_d(nn, strategy):
    """ISSUE 8 acceptance: per-device resident bytes of a degree-d
    period's layer are <= (replicated layer bytes) / d x 1.1 — in fact
    exactly 1/d, chunk geometry is exact."""
    w = workload(nn, batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, strategy)
    for run in prog.runs("fp"):
        layer_full = float((w.n(run.layer - 1) + 1) * w.n(run.layer)
                           * CFG.bytes_per_value)
        assert run.param_bytes <= layer_full / run.degree * 1.1
        assert run.param_bytes == layer_full / run.degree

    tracker = ResidencyTracker(prog, mode="sharded")
    full = replicated_model_bytes(prog)
    # peak per device is bounded by the sum of 1/d_i chunks, far below 1x
    assert max(tracker.peak_bytes()) <= sum(
        r.param_bytes for r in prog.runs("fp"))
    assert tracker.peak_ratio() < 1.0
    # on the uniform part of the ring: acquisition equals the chunk sum
    # for devices in every window
    in_all = set(range(N_DEV))
    for r in prog.runs("fp"):
        in_all &= set(r.devices)
    for d in in_all:
        assert tracker.timeline()[0].live_bytes[d] == pytest.approx(
            sum(r.param_bytes for r in prog.runs("fp")))
    assert full == pytest.approx(sum(
        (w.n(i - 1) + 1) * w.n(i) * CFG.bytes_per_value
        for i in range(1, w.l + 1)))


@pytest.mark.parametrize("nn", ["NN1", "NN2"])
def test_free_releases_at_exactly_scheduled_periods(nn):
    """FREE measurably reduces live bytes at exactly the param-FREE
    periods (the BP mirror periods), and the ledger drains to zero."""
    w = workload(nn, batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    tracker = ResidencyTracker(prog, mode="sharded")
    scheduled = sorted({f.period for f in prog.frees("param")})
    assert scheduled == list(range(w.l + 1, 2 * w.l + 1))  # Eq. 11 mirrors
    assert tracker.release_periods() == scheduled
    assert tracker.final_bytes() == (0.0,) * N_DEV
    # live bytes are non-increasing over the epoch (acquisition up front)
    timeline = tracker.timeline()
    for prev, cur in zip(timeline, timeline[1:]):
        assert all(c <= p for p, c in zip(prev.live_bytes, cur.live_bytes))


def test_replicated_tracker_is_flat_full_model():
    w = workload("NN1", batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    tracker = ResidencyTracker(prog, mode="replicated")
    full = replicated_model_bytes(prog)
    assert tracker.peak_ratio() == 1.0
    for snap in tracker.timeline():
        assert snap.live_bytes == (full,) * N_DEV
    assert tracker.release_periods() == []


def test_sharded_tracker_refuses_v1_programs():
    w = workload("NN1", batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    v1 = dataclasses.replace(prog, version=1)
    with pytest.raises(ValueError, match="recompile"):
        ResidencyTracker(v1, mode="sharded")
    ResidencyTracker(v1, mode="replicated")   # oracle accounting is fine


# ------------------------------------------------- executor bit-equivalence


def _trees_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("kernel_mode", ["ref", "pallas_interpret"])
@pytest.mark.parametrize("nn", ["NN1", "NN2"])
def test_sharded_matches_replicated_bit_for_bit(mesh, nn, kernel_mode):
    """Losses and grads of the sharded executor equal the replicated
    oracle exactly (same chunk, same device, same fp ops)."""
    w = workload(nn, batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    ex_r = ProgramExecutor(prog, mesh, kernel_mode=kernel_mode)
    ex_s = ProgramExecutor(prog, mesh, kernel_mode=kernel_mode,
                           residency="sharded")
    params = fcnn.init(jax.random.PRNGKey(0), w.layer_sizes)
    batch = _batch(w, 8)

    loss_r, grads_r = jax.value_and_grad(ex_r.loss_fn)(params, batch)
    sp = ex_s.shard_params(params)
    loss_s, sgrads = jax.value_and_grad(ex_s.loss_fn)(sp, batch)
    np.testing.assert_array_equal(np.asarray(loss_r), np.asarray(loss_s))
    assert _trees_equal(grads_r, ex_s.gather_params(sgrads))


def test_shard_gather_round_trip(mesh):
    w = workload("NN1", batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    ex = ProgramExecutor(prog, mesh, residency="sharded", kernel_mode="ref")
    params = fcnn.init(jax.random.PRNGKey(7), w.layer_sizes)
    assert _trees_equal(params, ex.gather_params(ex.shard_params(params)))


def test_sharded_executor_refuses_v1_programs(mesh):
    w = workload("NN1", batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    v1 = dataclasses.replace(prog, version=1)
    with pytest.raises(ValueError, match="schema-v2"):
        ProgramExecutor(v1, mesh, residency="sharded")
    ProgramExecutor(v1, mesh)                 # replicated oracle still runs


@pytest.mark.parametrize("kernel_mode", ["ref", "pallas_interpret"])
def test_five_step_adam_trajectory_matches(mesh, kernel_mode):
    """5 Adam steps through the façade: gathered sharded params equal the
    replicated oracle's params bit-for-bit (elementwise optimizer ->
    identical per-chunk update)."""
    w = workload("NN1", batch_size=8)
    opt = adam(1e-3)
    exes = {
        res: rexec.compile(w, CFG, mesh, strategy="orrm", residency=res,
                           kernel_mode=kernel_mode)
        for res in ("sharded", "replicated")
    }
    states = {res: exe.init_state(jax.random.PRNGKey(0), opt)
              for res, exe in exes.items()}
    step_fns = {res: exe.train_step(opt, donate=False)
                for res, exe in exes.items()}
    losses = {res: [] for res in exes}
    for i in range(5):
        batch = _batch(w, 8, seed=i)
        for res in exes:
            states[res], metrics = step_fns[res](states[res], batch)
            losses[res].append(float(metrics["loss"]))
    assert losses["sharded"] == losses["replicated"]
    gathered = exes["sharded"].gather_params(states["sharded"]["params"])
    assert _trees_equal(gathered, states["replicated"]["params"])


def test_off_window_chunks_stay_exactly_zero(mesh):
    """Zero placeholder chunks on off-window devices get zero grads and
    stay exactly zero through training — the sharded layout never leaks
    mass into chunks the schedule says are not resident."""
    w = workload("NN1", batch_size=8)
    exe = rexec.compile(w, CFG, mesh, residency="sharded",
                        kernel_mode="ref")
    opt = adam(1e-2)
    state = exe.init_state(jax.random.PRNGKey(0), opt)
    step = exe.train_step(opt, donate=False)
    for i in range(3):
        state, _ = step(state, _batch(w, 8, seed=i))
    for lay, lp in zip(exe.executor._layout, state["params"]["layers"]):
        off = sorted(set(range(N_DEV)) - set(int(d) for d in lay.window))
        for d in off:
            assert not np.asarray(lp["w"][d]).any()
            assert not np.asarray(lp["b"][d]).any()


# ------------------------------------------------------------------ façade


def test_facade_compile_surface(mesh):
    w = workload("NN2", batch_size=8)
    exe = rexec.compile(w, CFG, mesh, strategy="rrm", residency="sharded",
                        kernel_mode="ref")
    assert isinstance(exe, rexec.Executable)
    assert isinstance(exe.program, PeriodProgram)
    assert exe.program.version == 2
    assert exe.program.strategy == "rrm"
    assert exe.residency == "sharded"
    assert exe.tracker.peak_ratio() < 1.0
    # loss_fn composes with jit/grad on the residency layout
    params = exe.shard_params(fcnn.init(jax.random.PRNGKey(0),
                                        w.layer_sizes))
    loss = jax.jit(exe.loss_fn)(params, _batch(w, 8))
    assert np.isfinite(float(loss))
    # degrade swaps the kernel dispatch and reports the previous mode
    assert exe.degrade("ref") == "ref"


def test_facade_rejects_bad_residency(mesh):
    w = workload("NN1", batch_size=8)
    with pytest.raises(ValueError, match="residency"):
        rexec.compile(w, CFG, mesh, residency="holographic")


def test_old_entry_points_are_deprecation_shims(mesh):
    """The PR-6 surface stays importable and functional but warns."""
    from repro import deprecation

    deprecation.reset()     # shims warn once per process: re-arm
    w = workload("NN1", batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    with pytest.warns(DeprecationWarning, match="repro.exec.compile"):
        step, ex = rexec.build_train_step(  # lint: allow-deprecated
            prog, mesh, adam(1e-3), kernel_mode="ref")
    assert isinstance(ex, ProgramExecutor) and ex.residency == "replicated"
    with pytest.warns(DeprecationWarning, match="repro.exec.compile"):
        step, ex = steps_lib.build_fcnn_program_step(  # lint: allow-deprecated
            prog, mesh, kernel_mode="ref")
    state = steps_lib.init_fcnn_program_state(
        prog, steps_lib.TrainSettings(), jax.random.PRNGKey(0))
    state, metrics = step(state, _batch(w, 8))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


def test_degraded_runner_sharded_state_is_full_layout(mesh):
    """The degraded runner keeps canonical full-layout state (checkpoint
    portability across replans); its sharded step slices once at step
    start.  One jitted step must agree bit-for-bit with the replicated
    runner's step."""
    from repro.exec.api import Executable

    w = workload("NN1", batch_size=8)
    prog = compile_fcnn_program(w, CFG, N_DEV, "orrm")
    opt = adamw(1e-3)
    params = fcnn.init(jax.random.PRNGKey(0), w.layer_sizes)
    batch = _batch(w, 8)

    exe = Executable.from_program(prog, mesh, residency="sharded",
                                  kernel_mode="ref")

    @jax.jit
    def sharded_step(params, opt_state, batch, i):
        sp = exe.shard_params(params)
        loss, sgrads = jax.value_and_grad(exe.loss_fn)(sp, batch)
        grads = exe.gather_params(sgrads)
        return opt.update(grads, opt_state, params, i) + (loss,)

    ex_r = ProgramExecutor(prog, mesh, kernel_mode="ref")

    @jax.jit
    def replicated_step(params, opt_state, batch, i):
        loss, grads = jax.value_and_grad(ex_r.loss_fn)(params, batch)
        return opt.update(grads, opt_state, params, i) + (loss,)

    p_s, o_s, l_s = sharded_step(params, opt.init(params), batch, 0)
    p_r, o_r, l_r = replicated_step(params, opt.init(params), batch, 0)
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_r))
    assert _trees_equal(p_s, p_r)
