"""Fault-tolerance plumbing (ISSUE 7 satellites): StragglerMonitor window
regression, TrainingSupervisor fatal passthrough, checkpoint
crash-atomicity, and ElasticPlanner membership-change coverage."""

import dataclasses
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.onoc_model import FCNNWorkload
from repro.core.simulator import simulate_epoch
from repro.runtime.elastic import ElasticPlanner
from repro.runtime.fault_tolerance import StragglerMonitor, TrainingSupervisor
from repro.runtime.faults import DeviceLossFault


# ------------------------------------------------------------- straggler


def test_straggler_monitor_honors_window():
    """Regression: ``window`` was ignored (the deque default hardcoded
    maxlen=32), so a configured window never took effect."""
    mon = StragglerMonitor(window=8)
    assert mon._times.maxlen == 8
    for i in range(100):
        mon.observe(i, 1.0)
    assert len(mon._times) == 8

    big = StragglerMonitor(window=64)
    assert big._times.maxlen == 64
    for i in range(100):
        big.observe(i, 1.0)
    assert len(big._times) == 64


def test_straggler_window_affects_detection():
    """A short window forgets the fast history: after enough slow steps the
    median catches up and the same duration stops counting as straggling."""
    short = StragglerMonitor(window=8, deadline_factor=2.0)
    for i in range(8):
        short.observe(i, 0.1)
    flags = [short.observe(8 + i, 1.0) for i in range(6)]
    assert flags[0] is True          # 1.0 vs median 0.1
    assert flags[-1] is False        # slow steps now dominate the window
    long = StragglerMonitor(window=32, deadline_factor=2.0)
    for i in range(8):
        long.observe(i, 0.1)
    flags = [long.observe(8 + i, 1.0) for i in range(6)]
    assert all(flags)                # 32-window median still 0.1


# ------------------------------------------------------------ supervisor


def _batches():
    while True:
        yield {"x": 0}


def test_supervisor_fatal_exceptions_propagate():
    with tempfile.TemporaryDirectory() as tmp:
        sup = TrainingSupervisor(Checkpointer(tmp), checkpoint_every=0,
                                 max_retries=5, backoff_s=0.0,
                                 fatal=(DeviceLossFault,))
        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            raise DeviceLossFault(0, 1, (3,))

        with pytest.raises(DeviceLossFault):
            sup.run({"w": jnp.zeros(())}, step_fn, _batches(), 4)
        assert calls["n"] == 1           # no retry of a fatal fault


def test_supervisor_still_retries_non_fatal():
    with tempfile.TemporaryDirectory() as tmp:
        sup = TrainingSupervisor(Checkpointer(tmp), checkpoint_every=0,
                                 max_retries=3, backoff_s=0.0,
                                 fatal=(DeviceLossFault,))
        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            if calls["n"] <= 2:
                raise RuntimeError("transient")
            return state, {}

        state, hist = sup.run({"w": jnp.zeros(())}, step_fn, _batches(), 1)
        assert calls["n"] == 3 and len(hist) == 1


# ------------------------------------------------- checkpoint atomicity


def _state(v: float):
    return {"w": jnp.full((4,), v), "step": jnp.asarray(int(v), jnp.int32)}


def test_checkpoint_crash_atomicity(monkeypatch):
    """A crash mid-write (partial temp dir) must not corrupt the latest
    checkpoint: latest_step resolves to the previous complete step and
    restart succeeds."""
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, keep=3)
        ck.save(1, _state(1.0), blocking=True)
        assert latest_step(tmp) == 1

        # kill the write mid-flight: np.save succeeds for the first leaf
        # then dies, leaving a partial tmp.3 and no step_3
        real_save = np.save
        calls = {"n": 0}

        def dying_save(path, arr):
            calls["n"] += 1
            if calls["n"] >= 2:
                raise OSError("simulated crash mid-write")
            real_save(path, arr)

        monkeypatch.setattr(np, "save", dying_save)
        with pytest.raises(OSError):
            ck.save(3, _state(3.0), blocking=True)
        monkeypatch.setattr(np, "save", real_save)

        assert os.path.isdir(os.path.join(tmp, "tmp.3"))      # the corpse
        assert not os.path.isdir(os.path.join(tmp, "step_3"))
        assert latest_step(tmp) == 1                          # unharmed

        restored = ck.restore(1, _state(0.0))
        np.testing.assert_array_equal(restored["w"], np.full((4,), 1.0))
        assert int(restored["step"]) == 1

        # restart path: the next save at the same step works fine
        ck2 = Checkpointer(tmp, keep=3)
        ck2.save(3, _state(3.0), blocking=True)
        assert latest_step(tmp) == 3


def test_async_crash_leaves_previous_checkpoint(monkeypatch):
    """Same contract for the async path: a background writer that dies
    leaves latest_step at the previous complete checkpoint."""
    with tempfile.TemporaryDirectory() as tmp:
        ck = Checkpointer(tmp, keep=3)
        ck.save(2, _state(2.0), blocking=True)

        def always_die(path, arr):
            raise OSError("simulated crash mid-write")

        monkeypatch.setattr(np, "save", always_die)
        ck.save(4, _state(4.0), blocking=False)
        ck.wait()      # thread died; its exception stays in the thread
        assert latest_step(tmp) == 2


# ------------------------------------------------------ elastic shrink


def test_elastic_shrink_degrees_stay_feasible():
    """8 -> 6 -> 4 devices: every replanned program has divisor-feasible
    degrees on the shrunken ring and validates."""
    w = FCNNWorkload([32, 16, 8, 10], batch_size=8)
    planner = ElasticPlanner(w, dataclasses.replace(onoc_config(), m=8))
    for n in (8, 6, 4):
        cfg, plan, program = planner.replan_program(n)
        assert cfg.m == n and program.n_devices == n
        for i, d in enumerate(program.degrees, start=1):
            assert n % d == 0, f"{n} devices: degree {d} not a divisor"
            assert w.n(i) % d == 0
        for run in program.runs():
            assert all(0 <= dev < n for dev in run.devices)


def test_elastic_shrink_lemma1_monotone():
    """Lemma 1: the optimal epoch time can only get worse as cores are
    taken away (the feasible allocation set shrinks)."""
    w = workload("NN1", batch_size=64)
    base = onoc_config(lambda_max=64)
    planner = ElasticPlanner(w, base)
    times = []
    for m in (1000, 500, 100, 8, 6, 4):
        cfg, cores, _ = planner.plan_for(m)
        tr = simulate_epoch(w, cfg, cores_per_period=cores)
        times.append(tr.total_s)
        assert max(cores) <= m
    assert times == sorted(times), (
        f"epoch time not monotone in shrinking core count: {times}")


def test_elastic_replan_program_costs_match_simulator():
    """The replanned program's cost annotations equal simulate_epoch on the
    shrunken config (the validator's cost contract, end to end)."""
    w = FCNNWorkload([32, 16, 8, 10], batch_size=8)
    planner = ElasticPlanner(w, dataclasses.replace(onoc_config(), m=8))
    for n in (6, 4):
        cfg, plan, program = planner.replan_program(n)
        tr = simulate_epoch(w, cfg,
                            cores_per_period=list(program.onoc_cores))
        assert program.total_s == pytest.approx(tr.total_s, rel=1e-12)
