"""Microbenchmark of the FCNN hot loop: fused-kernel dispatch vs a plain
einsum implementation, forward and forward+backward.

On TPU the fused path runs the Pallas forward + custom-VJP dgrad/wgrad
kernels; on CPU it dispatches to the jnp oracle, so the comparison
degenerates to oracle-vs-einsum (≈parity) but keeps the harness exercised
and the JSON schema stable across PRs — the perf trajectory is tracked by
``benchmarks/run.py --json``.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

# Reduced NN1: real NN1 layer-1 geometry (784 in) at batch 128, plus the
# 10-class output period.  Small enough for CPU CI, shaped like the paper.
SHAPES = (
    ("nn1_layer1", 128, 784, 1000, "sigmoid"),
    ("nn1_output", 128, 500, 10, "none"),
)
WARMUP = 2
ITERS = 10


def _einsum_layer(x, w, b, activation):
    z = jnp.einsum("bi,io->bo", x, w,
                   preferred_element_type=jnp.float32) + b.astype(jnp.float32)
    if activation == "sigmoid":
        z = jax.nn.sigmoid(z)
    return z.astype(x.dtype)


def _time(fn, *args) -> float:
    for _ in range(WARMUP):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(ITERS):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / ITERS


def run() -> list[dict]:
    rng = np.random.default_rng(11)
    rows = []
    for name, m, k, n, act in SHAPES:
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
        b = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
        t = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)

        fused_fwd = jax.jit(lambda x, w, b: ops.fcnn_layer(x, w, b, act))
        einsum_fwd = jax.jit(lambda x, w, b: _einsum_layer(x, w, b, act))

        def _loss(fwd):
            def f(x, w, b):
                y = fwd(x, w, b)
                return jnp.mean((y.astype(jnp.float32) - t) ** 2)
            return f

        fused_fwdbwd = jax.jit(jax.grad(
            _loss(lambda x, w, b: ops.fcnn_layer(x, w, b, act)),
            argnums=(0, 1, 2)))
        einsum_fwdbwd = jax.jit(jax.grad(
            _loss(lambda x, w, b: _einsum_layer(x, w, b, act)),
            argnums=(0, 1, 2)))

        fwd_fused_s = _time(fused_fwd, x, w, b)
        fwd_einsum_s = _time(einsum_fwd, x, w, b)
        bwd_fused_s = _time(fused_fwdbwd, x, w, b)
        bwd_einsum_s = _time(einsum_fwdbwd, x, w, b)
        rows.append({
            "case": name, "m": m, "k": k, "n": n, "act": act,
            "backend": jax.default_backend(),
            "fwd_fused_us": 1e6 * fwd_fused_s,
            "fwd_einsum_us": 1e6 * fwd_einsum_s,
            "fwdbwd_fused_us": 1e6 * bwd_fused_s,
            "fwdbwd_einsum_us": 1e6 * bwd_einsum_s,
            "fwd_speedup": fwd_einsum_s / max(fwd_fused_s, 1e-12),
            "fwdbwd_speedup": bwd_einsum_s / max(bwd_fused_s, 1e-12),
        })
    return rows


# ---------------------------------------------------- fused softmax/xent

# Output-period shapes: the paper's 10-class layers at both batch sizes,
# plus a wide-vocab row so the class-tile streaming actually loops.
XENT_SHAPES = (
    ("nn1_output_b64", 64, 10),
    ("nn1_output_b128", 128, 10),
    ("wide_vocab_b128", 128, 4096),
)


def _jnp_xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.mean(-jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0])


def run_softmax_xent() -> list[dict]:
    """Fused softmax/cross-entropy dispatch vs plain jnp, fwd and fwd+bwd."""
    rng = np.random.default_rng(13)
    rows = []
    for name, b, n in XENT_SHAPES:
        logits = jnp.asarray(rng.normal(size=(b, n)) * 2, jnp.float32)
        labels = jnp.asarray(rng.integers(0, n, size=b), jnp.int32)

        fused_fwd = jax.jit(lambda x, y: ops.softmax_xent(x, y))
        jnp_fwd = jax.jit(_jnp_xent)
        fused_fwdbwd = jax.jit(jax.grad(lambda x, y: ops.softmax_xent(x, y)))
        jnp_fwdbwd = jax.jit(jax.grad(_jnp_xent))

        fwd_fused_s = _time(fused_fwd, logits, labels)
        fwd_jnp_s = _time(jnp_fwd, logits, labels)
        bwd_fused_s = _time(fused_fwdbwd, logits, labels)
        bwd_jnp_s = _time(jnp_fwdbwd, logits, labels)
        rows.append({
            "case": name, "b": b, "n": n,
            "backend": jax.default_backend(),
            "fwd_fused_us": 1e6 * fwd_fused_s,
            "fwd_jnp_us": 1e6 * fwd_jnp_s,
            "fwdbwd_fused_us": 1e6 * bwd_fused_s,
            "fwdbwd_jnp_us": 1e6 * bwd_jnp_s,
            "fwd_speedup": fwd_jnp_s / max(fwd_fused_s, 1e-12),
            "fwdbwd_speedup": bwd_jnp_s / max(bwd_fused_s, 1e-12),
        })
    return rows


if __name__ == "__main__":
    for r in run() + run_softmax_xent():
        print(r)
