"""Paper Table 7: prediction accuracy of the optimal core count.

For every NN benchmark, compare Lemma 1's m_i* against the brute-force
simulated optimum over 1..1000 cores, averaged over batch sizes {1,8,32,64}
and wavelengths {8,64}.  Reports the published-formula APE, the
plateau-aware APE (argmin-stable metric, see onoc_model.prediction_error)
and the APD, for both the raw Lemma-1 prediction and the closed-form
plateau refinement (beyond-paper).
"""

from __future__ import annotations

import numpy as np

from repro.configs.nn_benchmarks import NN_BENCHMARKS, WAVELENGTHS
from repro.core.onoc_model import FCNNWorkload, ONoCConfig, prediction_error

BATCHES = (1, 8, 32, 64)


def run() -> list[dict]:
    rows = []
    for name, sizes in NN_BENCHMARKS.items():
        for refined in (False, True):
            vals = []
            for bs in BATCHES:
                for lam in WAVELENGTHS:
                    w = FCNNWorkload(sizes, batch_size=bs)
                    cfg = ONoCConfig(lambda_max=lam)
                    vals.append(prediction_error(w, cfg,
                                                 refine_plateau=refined))
            raw, plateau, apd = np.mean(vals, axis=0)
            rows.append({
                "nn": name,
                "variant": "refined" if refined else "paper-faithful",
                "ape_raw_pct": 100 * raw,
                "ape_plateau_pct": 100 * plateau,
                "apd_pct": 100 * apd,
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
