"""Paper Tables 8 & 9 (+ Fig. 8/9): the optimal solution vs FNP (fixed 200
cores) and FGP (one neuron per core) — training-time improvement and energy
difference per NN benchmark × batch size, averaged over wavelengths 8/64.
Fixed Mapping strategy throughout (paper §5.3)."""

from __future__ import annotations

import numpy as np

from repro.configs.nn_benchmarks import NN_BENCHMARKS, WAVELENGTHS
from repro.core import (
    FCNNWorkload,
    ONoCConfig,
    fgp_cores,
    fnp_cores,
    map_cores,
    onoc_energy,
    optimal_cores,
    simulate_epoch,
)
from repro.core.analyses import analyze_mapping

BATCHES = (1, 8, 64, 128)


def _time_energy(w, cfg, cores):
    mp = map_cores(w, cfg, "fm", cores)
    tr = simulate_epoch(w, cfg, mapping=mp)
    rep = analyze_mapping(w, mp)
    e = onoc_energy(tr, mp, rep.state_transitions)
    return tr.total_s, e.total_j


def run() -> list[dict]:
    rows = []
    for name, sizes in NN_BENCHMARKS.items():
        for bs in BATCHES:
            t_imp = {"fnp": [], "fgp": []}
            e_diff = {"fnp": [], "fgp": []}
            for lam in WAVELENGTHS:
                w = FCNNWorkload(sizes, batch_size=bs)
                cfg = ONoCConfig(lambda_max=lam)
                t_opt, e_opt = _time_energy(
                    w, cfg, optimal_cores(w, cfg, refine_plateau=True))
                t_fnp, e_fnp = _time_energy(w, cfg, fnp_cores(w, cfg))
                t_fgp, e_fgp = _time_energy(w, cfg, fgp_cores(w, cfg))
                t_imp["fnp"].append((t_fnp - t_opt) / t_fnp)
                t_imp["fgp"].append((t_fgp - t_opt) / t_fgp)
                e_diff["fnp"].append((e_fnp - e_opt) / e_fnp)
                e_diff["fgp"].append((e_fgp - e_opt) / e_fgp)
            rows.append({
                "nn": name, "batch": bs,
                "time_improvement_vs_fnp_pct": 100 * float(np.mean(t_imp["fnp"])),
                "time_improvement_vs_fgp_pct": 100 * float(np.mean(t_imp["fgp"])),
                "energy_saving_vs_fnp_pct": 100 * float(np.mean(e_diff["fnp"])),
                "energy_saving_vs_fgp_pct": 100 * float(np.mean(e_diff["fgp"])),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
