"""Static analyzer benchmark (ISSUE 9): per-device analysis wall time at
both levels (``fast``: expansion + endpoints + happens-before + memory;
``full``: + cost contract + shape abstract interpretation) for every
paper benchmark on the 8-device ring, plus the corruption-corpus
regression row — every seeded corruption must pass the SPMD validator
(its blind spot) yet be rejected by the analyzer with the expected
message."""

from __future__ import annotations

import re
import time

from repro.configs.nn_benchmarks import NN_BENCHMARKS, onoc_config, workload
from repro.core.allocation import MappingStrategy
from repro.exec.analysis import (
    ProgramAnalysisError,
    analyze_program,
    corruption_corpus,
)
from repro.exec.program import compile_fcnn_program
from repro.exec.validate import ProgramValidationError, validate_program

N_DEV = 8


def run() -> list[dict]:
    rows = []
    cfg = onoc_config(lambda_max=64)
    for nn in sorted(NN_BENCHMARKS):
        w = workload(nn, batch_size=64)
        prog = compile_fcnn_program(w, cfg, N_DEV, MappingStrategy.ORRM)
        try:
            t0 = time.perf_counter()
            analyze_program(prog, level="fast")
            fast_us = 1e6 * (time.perf_counter() - t0)
            t0 = time.perf_counter()
            report = analyze_program(prog, w, cfg, level="full")
            full_us = 1e6 * (time.perf_counter() - t0)
            clean = True
        except ProgramValidationError:
            fast_us = full_us = float("nan")
            report = None
            clean = False
        rows.append({
            "case": f"{nn.lower()}_orrm",
            "nn": nn,
            "strategy": "orrm",
            "n_devices": N_DEV,
            "instructions": 0 if report is None else report.n_instructions,
            "device_ops": 0 if report is None else report.n_device_ops,
            "hb_edges": 0 if report is None else report.n_hb_edges,
            "analyze_fast_us": fast_us,
            "analyze_full_us": full_us,
            "clean": clean,
        })

    # the corpus regression: derived from the NN1 program, each entry in a
    # validator blind spot (validator_passes) and analyzer-rejected with
    # the expected message (analyzer_rejects)
    w = workload("NN1", batch_size=64)
    prog = compile_fcnn_program(w, cfg, N_DEV, MappingStrategy.ORRM)
    entries = corruption_corpus(prog, seed=0)
    validator_passes = analyzer_rejects = 0
    for e in entries:
        try:
            validate_program(e.program, w, cfg)
            validator_passes += 1
        except ProgramValidationError:
            pass
        try:
            analyze_program(e.program, w, cfg, level="full")
        except ProgramAnalysisError as err:
            if re.search(e.match, str(err)):
                analyzer_rejects += 1
    rows.append({
        "case": "corruption_corpus",
        "n_entries": len(entries),
        "validator_passes": validator_passes,
        "analyzer_rejects": analyzer_rejects,
        "corpus_ok": bool(validator_passes == len(entries)
                          and analyzer_rejects == len(entries)),
    })
    return rows
