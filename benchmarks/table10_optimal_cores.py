"""Paper Table 10: the optimal number of cores per layer for every NN
benchmark under (batch, wavelengths) in {1, 8} x {8, 64}."""

from __future__ import annotations

from repro.configs.nn_benchmarks import NN_BENCHMARKS
from repro.core.onoc_model import FCNNWorkload, ONoCConfig, optimal_cores


def run() -> list[dict]:
    rows = []
    for name, sizes in NN_BENCHMARKS.items():
        for bs in (1, 8):
            for lam in (8, 64):
                w = FCNNWorkload(sizes, batch_size=bs)
                cfg = ONoCConfig(lambda_max=lam)
                rows.append({
                    "nn": name, "batch": bs, "wavelengths": lam,
                    "optimal_cores": optimal_cores(w, cfg),
                    "refined_cores": optimal_cores(w, cfg,
                                                   refine_plateau=True),
                })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
