"""Roofline table generator: reads results/dryrun.json (written by
repro.launch.dryrun) and renders the EXPERIMENTS.md §Roofline table —
per (arch × shape × mesh): the three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio and per-device HBM residency."""

from __future__ import annotations

import json
import os


def run(path: str = "results/dryrun.json") -> list[dict]:
    if not os.path.exists(path):
        return [{"error": f"{path} not found; run repro.launch.dryrun first"}]
    with open(path) as f:
        data = json.load(f)
    rows = []
    for key in sorted(data):
        r = data[key]
        if r.get("skipped"):
            rows.append({"cell": key, "status": "skipped",
                         "reason": r["reason"][:60]})
            continue
        if not r.get("ok"):
            rows.append({"cell": key, "status": "FAIL",
                         "error": r.get("error", "?")[:80]})
            continue
        rows.append({
            "cell": key,
            "compute_ms": round(1e3 * r["compute_s"], 2),
            "memory_ms": round(1e3 * r["memory_s"], 2),
            "collective_ms": round(1e3 * r["collective_s"], 2),
            "bottleneck": r["bottleneck"],
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "hbm_gb_per_dev": round(r["peak_memory_per_device"] / 1e9, 2),
        })
    return rows


def markdown_table(path: str = "results/dryrun.json") -> str:
    rows = run(path)
    out = ["| cell | compute ms | memory ms | collective ms | bottleneck | "
           "useful-FLOPs | HBM GB/dev |",
           "|---|---|---|---|---|---|---|"]
    for r in rows:
        if "status" in r:
            out.append(f"| {r['cell']} | — | — | — | {r['status']} | — | — |")
        else:
            out.append(
                f"| {r['cell']} | {r['compute_ms']} | {r['memory_ms']} | "
                f"{r['collective_ms']} | {r['bottleneck']} | "
                f"{r['useful_flops_ratio']} | {r['hbm_gb_per_dev']} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(markdown_table())
