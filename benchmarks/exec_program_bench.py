"""Period-program compiler benchmark: compile wall time, instruction mix,
serialized size, and the cost contract (program annotations must equal
``core.simulator.simulate_epoch``) for every paper benchmark x mapping
strategy on the 8-device executor ring."""

from __future__ import annotations

import time

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.allocation import MappingStrategy
from repro.core.planner import plan_fcnn, ring_mesh_axes
from repro.core.simulator import simulate_epoch
from repro.exec.program import compile_program

N_DEV = 8


def run() -> list[dict]:
    rows = []
    cfg = onoc_config(lambda_max=64)
    for nn in ("NN1", "NN2", "NN3"):
        w = workload(nn, batch_size=64)
        for strat in MappingStrategy:
            plan = plan_fcnn(w, cfg, ring_mesh_axes(N_DEV), strategy=strat)
            t0 = time.perf_counter()
            prog = compile_program(plan, w, cfg, N_DEV)
            compile_us = 1e6 * (time.perf_counter() - t0)
            trace = simulate_epoch(w, cfg, mapping=plan.mapping)
            rows.append({
                "case": f"{nn.lower()}_{strat.value}",
                "nn": nn,
                "strategy": strat.value,
                "n_devices": N_DEV,
                "instructions": len(prog.instructions),
                "runs": len(prog.runs()),
                "sends": len(prog.sends()),
                "frees": len(prog.frees()),
                "window_frees": len(prog.frees("window")),
                "param_frees": len(prog.frees("param")),
                "json_bytes": len(prog.to_json()),
                "compile_us": compile_us,
                "program_total_s": prog.total_s,
                "sim_total_s": trace.total_s,
                "cost_match": bool(
                    prog.compute_s == trace.compute_s
                    and prog.comm_s == trace.comm_s),
            })
    return rows
