"""Fault-injection benchmark: degraded-epoch pricing + a live seeded
device-loss recovery scenario on the 8-device CPU ring.

Two result families:

  * pricing rows — ``expected_epoch_time`` on both backends for a paper
    workload under a representative degradation mix (wavelength comb loss,
    link degradation, straggling period, a transient RUN retry) plus a
    2-core device-loss burst: nominal vs degraded vs expected epoch time,
    recovery overhead split into prefix / retry / re-transition /
    replanned-epoch terms.

  * recovery row — a real ``DegradedModeRunner`` training run on forced
    CPU host devices: a seeded mid-run device loss triggers replanning
    (Lemma 1 on the survivors), program recompilation (statically
    re-validated) and checkpoint-resume; the row records the structured
    ``FaultReport`` and the max per-step loss deviation against a
    from-scratch run on the surviving mesh — the reproduction check pins
    it to fp tolerance (no sample skipped or repeated).
"""

from __future__ import annotations

import dataclasses
import tempfile

import numpy as np

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.core.simulator import ENoCBackend, ONoCBackend
from repro.runtime.faults import (
    FaultEvent,
    FaultKind,
    FaultSchedule,
    expected_epoch_time,
)

SEED = 0
N_STEPS = 8
N_DEVICES = 8
SIZES = (32, 16, 8, 10)
BATCH = 8


def _pricing_rows() -> list[dict]:
    w = workload("NN1", batch_size=64)
    cfg = onoc_config(lambda_max=64)
    schedule = FaultSchedule(events=(
        FaultEvent(kind=FaultKind.WAVELENGTH_DEGRADE, step=0, magnitude=0.5),
        FaultEvent(kind=FaultKind.LINK_DEGRADE, step=0, period=0,
                   magnitude=0.5),
        FaultEvent(kind=FaultKind.STRAGGLER, step=0, period=2,
                   magnitude=2.0),
        FaultEvent(kind=FaultKind.TRANSIENT_RUN, step=0, period=2,
                   device=2, count=1),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=3, device=0),
        FaultEvent(kind=FaultKind.DEVICE_LOSS, step=0, period=3, device=1),
    ), seed=SEED)
    rows = []
    for backend in (ONoCBackend(), ENoCBackend()):
        pr = expected_epoch_time(w, cfg, schedule, step=0, backend=backend)
        rows.append({
            "case": f"NN1-{backend.name}",
            "backend": backend.name,
            "nominal_s": pr.nominal_s,
            "degraded_s": pr.degraded_s,
            "loss_period": pr.loss_period,
            "survivors": pr.survivors,
            "prefix_s": pr.prefix_s,
            "re_transition_s": pr.re_transition_s,
            "replanned_epoch_s": pr.replanned_epoch_s,
            "retry_s": pr.retry_s,
            "retries": pr.retries,
            "expected_s": pr.expected_s,
            "overhead_pct": pr.overhead_pct,
        })
    return rows


def _recovery_row() -> dict:
    import jax
    from jax.sharding import Mesh

    from repro.checkpoint import Checkpointer
    from repro.core.onoc_model import FCNNWorkload
    from repro.data import Batcher, fcnn_classification_dataset
    from repro.models import fcnn
    from repro.optim import adam
    from repro.runtime.degraded import DegradedModeRunner

    cpu = jax.devices("cpu")
    if len(cpu) < N_DEVICES:
        return {"case": "device-loss-recovery", "skipped": True,
                "reason": f"need {N_DEVICES} CPU devices, have {len(cpu)}"}

    def mesh_factory(n: int) -> Mesh:
        return Mesh(np.asarray(cpu[:n]), ("cores",))

    w = FCNNWorkload(list(SIZES), batch_size=BATCH)
    cfg = dataclasses.replace(onoc_config(lambda_max=64), m=N_DEVICES)
    x, y = fcnn_classification_dataset(64, input_dim=SIZES[0], seed=3)
    params0 = fcnn.init(jax.random.PRNGKey(0), list(SIZES))
    opt = adam(1e-2)

    schedule = FaultSchedule.seeded_device_loss(
        SEED, n_steps=N_STEPS, n_devices=N_DEVICES, n_periods=2 * w.l)
    lost = [e.device for e in schedule.events]
    survivors = N_DEVICES - len(lost)

    with tempfile.TemporaryDirectory() as tmp:
        runner = DegradedModeRunner(
            workload=w, base_cfg=cfg, schedule=schedule,
            checkpointer=Checkpointer(tmp), optimizer=opt,
            n_devices=N_DEVICES, kernel_mode="ref", checkpoint_every=2,
            backoff_s=0.0, mesh_factory=mesh_factory)
        state, _, report = runner.run(
            params0, opt.init(params0),
            Batcher({"x": x, "y": y}, batch_size=BATCH), N_STEPS)

    with tempfile.TemporaryDirectory() as tmp:
        scratch = DegradedModeRunner(
            workload=w, base_cfg=dataclasses.replace(cfg, m=survivors),
            schedule=FaultSchedule(), checkpointer=Checkpointer(tmp),
            optimizer=opt, n_devices=survivors, kernel_mode="ref",
            checkpoint_every=2, backoff_s=0.0, mesh_factory=mesh_factory)
        _, _, _ = scratch.run(
            params0, opt.init(params0),
            Batcher({"x": x, "y": y}, batch_size=BATCH), N_STEPS)

    max_diff = max(
        abs(runner.losses[s] - scratch.losses[s]) for s in range(N_STEPS))
    return {
        "case": "device-loss-recovery",
        "loss_step": schedule.events[0].step,
        "loss_period": schedule.events[0].period,
        "lost_devices": lost,
        "survivors": survivors,
        "replans": len(report.replans),
        "resumed_from": report.resumed_from,
        "steps_completed": int(state["step"]),
        "max_loss_diff_vs_scratch": max_diff,
        "recovered": (len(report.replans) == 1
                      and int(state["step"]) == N_STEPS
                      and max_diff < 1e-4),
        "fault_report": report.to_dict(),
    }


def run() -> list[dict]:
    return _pricing_rows() + [_recovery_row()]
