"""Paper Tables 1-3 + Theorem 2: per-strategy hotspot level, state
transitions, max routing path / worst-case insertion loss (Eq. 19), and
max per-core memory (Eq. 20), on NN2 with the optimal allocation."""

from __future__ import annotations

from repro.configs.nn_benchmarks import NN_BENCHMARKS
from repro.core import (
    FCNNWorkload,
    MappingStrategy,
    ONoCConfig,
    map_cores,
    optimal_cores,
)
from repro.core.analyses import analyze_mapping


def run() -> list[dict]:
    rows = []
    for lam in (8, 64):
        w = FCNNWorkload(NN_BENCHMARKS["NN2"], batch_size=8)
        cfg = ONoCConfig(lambda_max=lam)
        cores = optimal_cores(w, cfg)
        for strat in MappingStrategy:
            mp = map_cores(w, cfg, strat, cores)
            rep = analyze_mapping(w, mp)
            rows.append({
                "wavelengths": lam,
                "strategy": strat.value,
                "hotspot_consecutive_periods": rep.hotspot_consecutive_periods,
                "state_transitions": rep.state_transitions,
                "max_path_hops": rep.max_path_length_hops,
                "worst_insertion_loss_db": round(rep.worst_insertion_loss_db, 2),
                "max_core_memory_mb": round(rep.max_memory_bytes / 1e6, 2),
            })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
