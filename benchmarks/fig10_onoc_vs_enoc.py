"""Paper Fig. 10: ONoC vs ENoC on NN2, Fixed Mapping, fixed core counts
{40, 65, 90, 150, 250, 350}, batch sizes {64, 128} — training time and
energy, plus the paper's headline averages (time reduction / energy
saving)."""

from __future__ import annotations

import numpy as np

from repro.configs.nn_benchmarks import ENOC_CORE_SWEEP, NN_BENCHMARKS
from repro.core import (
    ENoCBackend,
    FCNNWorkload,
    ONoCConfig,
    enoc_energy,
    fnp_cores,
    map_cores,
    onoc_energy,
    simulate_epoch,
)
from repro.core.analyses import analyze_mapping


def run() -> list[dict]:
    rows = []
    summary = {}
    for bs in (64, 128):
        t_red, e_red = [], []
        for fixed in ENOC_CORE_SWEEP:
            w = FCNNWorkload(NN_BENCHMARKS["NN2"], batch_size=bs)
            cfg = ONoCConfig(lambda_max=64)
            cores = fnp_cores(w, cfg, fixed)
            mp = map_cores(w, cfg, "fm", cores)
            rep = analyze_mapping(w, mp)
            tr_o = simulate_epoch(w, cfg, mapping=mp)
            tr_e = simulate_epoch(w, cfg, mapping=mp, backend=ENoCBackend())
            e_o = onoc_energy(tr_o, mp, rep.state_transitions)
            e_e = enoc_energy(tr_e, mp, rep.state_transitions)
            t_red.append((tr_e.total_s - tr_o.total_s) / tr_e.total_s)
            e_red.append((e_e.total_j - e_o.total_j) / e_e.total_j)
            rows.append({
                "batch": bs, "cores": fixed,
                "onoc_time_ms": 1e3 * tr_o.total_s,
                "enoc_time_ms": 1e3 * tr_e.total_s,
                "onoc_energy_mj": 1e3 * e_o.total_j,
                "enoc_energy_mj": 1e3 * e_e.total_j,
            })
        summary[bs] = {
            "avg_time_reduction_pct": 100 * float(np.mean(t_red)),
            "avg_energy_saving_pct": 100 * float(np.mean(e_red)),
        }
    rows.append({"summary": summary,
                 "paper_claims": {"time": {64: 21.02, 128: 12.95},
                                  "energy": {64: 47.85, 128: 39.27}}})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
