"""Benchmark regression gate (``make bench-gate``).

Runs ``benchmarks.run --json`` fresh (or takes ``--report PATH``) and
diffs it against the committed baseline (``BENCH_fcnn.json``).  Exits 1
when:

  * a reproduction check that PASSed in the baseline now FAILs or has
    disappeared from the report (deleting a check is a regression too), or
  * a gated timing ratio degrades by more than ``--slowdown`` (default
    20%) — the microbench speedups (fused vs reference implementation)
    and the executor's program-execution wall-time ratio
    (``exec_residency_bench``'s replicated-over-sharded step time, see
    ``_ratio_fields``).

Raw wall-clock fields are never compared — only timing *ratios*, which
are stable across machines since both sides of the ratio run on the same
box.  Even ratios flake on loaded CPU runners, so when the gate runs the
benchmarks itself it re-runs each ratio-gated benchmark ``--repeats``
times (default 3) and gates on the **median** ratio per case — a single
noisy run can no longer fail (or pass) the gate.  After an intentional change (new
checks, a real kernel win), refresh the baseline with ``make bench-json``
and commit the new snapshot.

Intentional baseline refreshes go through ``--refresh`` (``make
bench-refresh``): instead of hand-editing or wholesale overwriting
``BENCH_fcnn.json``, the gate runs the sweep (ratio fields snapshotted at
the per-case **minimum** across repeats — a conservative floor, so a
lucky fast run cannot tighten the gate), writes it as the new baseline,
and appends a summary of the *old* baseline to a ``"history"`` list
inside the file — the refresh trail rides along in the committed JSON.
``compare`` never reads ``"history"``.

  PYTHONPATH=src python -m benchmarks.gate [--baseline BENCH_fcnn.json]
      [--report PATH] [--slowdown 0.20] [--repeats 3] [--refresh]
"""

from __future__ import annotations

import argparse
import json
import statistics
import subprocess
import sys
import tempfile
import time

def _ratio_fields(name: str) -> tuple[str, ...]:
    """Gated ratio fields per benchmark.  Only ratios are compared across
    reports (both sides of a ratio run on the same box); benchmarks not
    listed here contribute checks but no timing gate."""
    if name.endswith("microbench"):
        return ("fwd_speedup", "fwdbwd_speedup")
    if name == "exec_residency_bench":
        return ("replicated_over_sharded_step",)
    if name == "serving_bench":
        return ("tok_s_ratio", "p99_ttft_ratio")
    return ()


def _check_key(line: str) -> str:
    """Stable identity of a check line: everything before the measured
    numbers ("check,table7,plateau-APE<=2.3% (paper claim)")."""
    head = line.split(" -> ")[0]
    return head.split(":")[0] if ":" in head else head


def _verdict(line: str) -> str | None:
    return line.rsplit("-> ", 1)[1].strip() if "-> " in line else None


def compare(base: dict, cur: dict, slowdown: float) -> list[str]:
    failures: list[str] = []

    cur_checks = {}
    for line in cur.get("checks", []):
        if _verdict(line) in ("PASS", "FAIL"):
            cur_checks[_check_key(line)] = line
    for line in base.get("checks", []):
        if _verdict(line) != "PASS":
            continue  # informational or already-failing: not gated
        key = _check_key(line)
        now = cur_checks.get(key)
        if now is None:
            failures.append(f"check disappeared (was PASS): {key}")
        elif _verdict(now) == "FAIL":
            failures.append(f"paper-claim regression: {now}")

    for name, bench in base.get("benchmarks", {}).items():
        fields = _ratio_fields(name)
        if not fields:
            continue
        cur_bench = cur.get("benchmarks", {}).get(name)
        if cur_bench is None:
            failures.append(f"gated benchmark disappeared: {name}")
            continue
        cur_rows = {r.get("case"): r for r in cur_bench["rows"]}
        for row in bench["rows"]:
            case = row.get("case")
            now = cur_rows.get(case)
            if now is None:
                failures.append(f"{name}: case {case!r} disappeared")
                continue
            for f in fields:
                if f in row and f in now and now[f] < (1 - slowdown) * row[f]:
                    failures.append(
                        f"{name}/{case}: {f} {row[f]:.3f} -> {now[f]:.3f} "
                        f"(>{slowdown:.0%} slowdown)")
    return failures


def merge_ratio_stats(reports: list[dict], reduce) -> dict:
    """Flake dampening: replace each ratio-gated row's timing ratios with
    ``reduce(samples)`` across ``reports`` (median when gating, min when
    refreshing the baseline).  The first report supplies everything else
    (checks, ungated rows)."""
    merged = reports[0]
    if len(reports) < 2:
        return merged
    for name, bench in merged.get("benchmarks", {}).items():
        fields = _ratio_fields(name)
        if not fields:
            continue
        samples: dict[tuple, list[float]] = {}
        for rep in reports:
            b = rep.get("benchmarks", {}).get(name)
            if b is None:
                continue
            for row in b["rows"]:
                for f in fields:
                    if f in row:
                        samples.setdefault((row.get("case"), f),
                                           []).append(row[f])
        for row in bench["rows"]:
            for f in fields:
                vals = samples.get((row.get("case"), f))
                if vals:
                    row[f] = reduce(vals)
    return merged


def merge_median_speedups(reports: list[dict]) -> dict:
    return merge_ratio_stats(reports, statistics.median)


def baseline_snapshot(base: dict) -> dict:
    """A compact summary of a baseline for the ``"history"`` trail: check
    pass/fail counts and every gated ratio value."""
    verdicts = [_verdict(c) for c in base.get("checks", [])]
    ratios = {}
    for name, bench in base.get("benchmarks", {}).items():
        for row in bench.get("rows", []):
            for f in _ratio_fields(name):
                if f in row:
                    ratios[f"{name}/{row.get('case')}/{f}"] = row[f]
    return {
        "checks_pass": sum(1 for v in verdicts if v == "PASS"),
        "checks_fail": sum(1 for v in verdicts if v == "FAIL"),
        "n_benchmarks": len(base.get("benchmarks", {})),
        "ratios": ratios,
    }


def refresh_baseline(base: dict, cur: dict, stamp: str | None = None) -> dict:
    """The new baseline on an intentional refresh: ``cur`` plus the old
    baseline's history trail extended with a snapshot of the old
    baseline itself.  ``compare`` ignores ``"history"`` entirely."""
    entry = {"refreshed": stamp or time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                                 time.gmtime()),
             "previous": baseline_snapshot(base)}
    out = dict(cur)
    out["history"] = list(base.get("history", [])) + [entry]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default="BENCH_fcnn.json")
    ap.add_argument("--report", default=None,
                    help="pre-computed benchmarks.run --json report "
                         "(default: run the benchmarks now)")
    ap.add_argument("--slowdown", type=float, default=0.20,
                    help="max tolerated microbench speedup-ratio drop")
    ap.add_argument("--repeats", type=int, default=3,
                    help="microbench re-runs; the gate compares the median "
                         "speedup per case (only when running fresh)")
    ap.add_argument("--refresh", action="store_true",
                    help="intentional baseline refresh: write the fresh "
                         "report (ratio fields at the per-case minimum "
                         "across repeats) as the new baseline, appending "
                         "a snapshot of the old baseline to its "
                         "\"history\" trail")
    args = ap.parse_args()

    with open(args.baseline) as f:
        base = json.load(f)

    if args.report:
        with open(args.report) as f:
            cur = json.load(f)
    else:
        report_path = tempfile.mktemp(suffix=".json", prefix="bench_gate_")
        print(f"# bench-gate: running benchmarks -> {report_path}")
        subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--json", report_path],
            check=True)
        with open(report_path) as f:
            reports = [json.load(f)]
        gated = [n for n in reports[0].get("benchmarks", {})
                 if _ratio_fields(n)]
        for rep in range(1, max(args.repeats, 1)):
            for name in gated:
                p = tempfile.mktemp(suffix=".json", prefix="bench_gate_")
                print(f"# bench-gate: timing-gated repeat {rep + 1}/"
                      f"{args.repeats}: {name}")
                subprocess.run(
                    [sys.executable, "-m", "benchmarks.run",
                     "--only", name, "--json", p], check=True)
                with open(p) as f:
                    reports.append(json.load(f))
        cur = merge_ratio_stats(
            reports, min if args.refresh else statistics.median)

    if args.refresh:
        refreshed = refresh_baseline(base, cur)
        accepted = compare(base, cur, args.slowdown)
        with open(args.baseline, "w") as f:
            json.dump(refreshed, f, indent=1)
        print(f"\n# bench-gate: refreshed {args.baseline} "
              f"({len(refreshed['history'])} history snapshot(s))")
        for msg in accepted:
            print(f"  accepted vs old baseline: {msg}")
        return

    failures = compare(base, cur, args.slowdown)
    if failures:
        print(f"\n# bench-gate: FAIL ({len(failures)} regressions "
              f"vs {args.baseline})")
        for msg in failures:
            print(f"  {msg}")
        sys.exit(1)
    n_checks = sum(1 for c in base.get("checks", []) if _verdict(c) == "PASS")
    print(f"\n# bench-gate: OK ({n_checks} gated checks held, "
          f"microbench within {args.slowdown:.0%} of {args.baseline})")


if __name__ == "__main__":
    main()
