"""Paper Fig. 7: per-layer time vs core count — compute, communication and
total for layer 3 of NN2 (batch 32, 64 wavelengths), FP, BP and combined.
Emits the curve samples and the three argmin points."""

from __future__ import annotations

import numpy as np

from repro.configs.nn_benchmarks import NN_BENCHMARKS
from repro.core.onoc_model import (
    FCNNWorkload,
    ONoCConfig,
    comm_time,
    compute_time,
)


def run(layer: int = 3, batch: int = 32, lam: int = 64,
        sample_every: int = 64) -> list[dict]:
    w = FCNNWorkload(NN_BENCHMARKS["NN2"], batch_size=batch)
    cfg = ONoCConfig(lambda_max=lam)
    l = w.l
    i_fp, i_bp = layer, 2 * l - layer + 1
    cap = min(cfg.m, w.n(layer))

    def t(i, m):
        return compute_time(w, cfg, i, m) + comm_time(w, cfg, i, m)

    ms = np.arange(1, cap + 1)
    fp = np.array([t(i_fp, m) for m in ms])
    bp = np.array([t(i_bp, m) for m in ms])
    both = fp + bp
    rows = []
    for m in range(sample_every, cap + 1, sample_every):
        rows.append({"cores": int(m),
                     "fp_us": 1e6 * float(fp[m - 1]),
                     "bp_us": 1e6 * float(bp[m - 1]),
                     "total_us": 1e6 * float(both[m - 1])})
    rows.append({
        "optimum_fp": int(ms[np.argmin(fp)]),
        "optimum_bp": int(ms[np.argmin(bp)]),
        "optimum_combined": int(ms[np.argmin(both)]),
        "paper_example": {"fp": 896, "bp": 704, "combined": 769},
    })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
