"""Weight-sharded residency benchmark (ISSUE 8 tentpole).

Two result families on the 8-device executor ring:

  * tracker rows — for each paper workload, walk the compiled ORRM
    program's residency annotations (``exec.residency.ResidencyTracker``)
    and check the tentpole claim statically: max per-device peak live
    parameter bytes <= 1.1 x replicated-model bytes / d (d = the smallest
    FP parallelism degree — a safe upper bound for mixed-degree rings),
    param FREEs release at exactly the Eq.-11 BP mirror periods, and the
    ledger drains to zero by period 2l.

  * timed row — a real sharded vs replicated ``Executable.train_step``
    on forced CPU host devices (kernel_mode="ref"): per-step wall time in
    both residency modes and their ratio ``replicated_over_sharded_step``
    (gated by benchmarks.gate — both sides run on the same box, so the
    ratio is stable where raw wall time is not), plus a bit-match check
    that the sharded loss equals the replicated oracle exactly.
"""

from __future__ import annotations

import dataclasses
import time

from repro.configs.nn_benchmarks import onoc_config, workload
from repro.exec.program import compile_fcnn_program
from repro.exec.residency import ResidencyTracker, replicated_model_bytes

N_DEV = 8
TIMED_SIZES = (128, 64, 32, 10)
TIMED_BATCH = 32
N_WARMUP = 3
N_TIMED = 20


def _tracker_rows() -> list[dict]:
    cfg = onoc_config(lambda_max=64)
    rows = []
    for nn in ("NN1", "NN2"):
        w = workload(nn, batch_size=64)
        prog = compile_fcnn_program(w, cfg, N_DEV, "orrm")
        tr = ResidencyTracker(prog, mode="sharded")
        full = replicated_model_bytes(prog)
        d_min = min(r.degree for r in prog.runs("fp"))
        peak = max(tr.peak_bytes())
        # layer i is dropped after its BP mirror period 2l-i+1, i.e. the
        # sharded tracker must release at every BP period l+1 .. 2l
        releases = tr.release_periods()
        free_ok = (releases == list(range(w.l + 1, 2 * w.l + 1))
                   and all(b == 0.0 for b in tr.final_bytes()))
        rows.append({
            "case": f"{nn.lower()}_residency",
            "nn": nn,
            "n_devices": N_DEV,
            "schema_version": prog.version,
            "replicated_bytes": full,
            "sharded_peak_bytes": peak,
            "peak_ratio": tr.peak_ratio(),
            "min_fp_degree": d_min,
            "peak_ok": bool(peak <= 1.1 * full / d_min),
            "release_periods": releases,
            "free_ok": free_ok,
        })
    return rows


def _timed_row() -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    import repro.exec as rexec
    from repro.core.onoc_model import FCNNWorkload
    from repro.data import fcnn_classification_dataset
    from repro.optim import adam

    cpu = jax.devices("cpu")
    if len(cpu) < N_DEV:
        return {"case": "timed_step", "skipped": True,
                "reason": f"need {N_DEV} CPU devices, have {len(cpu)}"}
    mesh = Mesh(np.asarray(cpu[:N_DEV]), ("cores",))

    w = FCNNWorkload(list(TIMED_SIZES), batch_size=TIMED_BATCH)
    cfg = dataclasses.replace(onoc_config(lambda_max=64), m=N_DEV)
    x, y = fcnn_classification_dataset(256, input_dim=TIMED_SIZES[0], seed=0)
    batch = {"x": jnp.asarray(x[:TIMED_BATCH]),
             "y": jnp.asarray(y[:TIMED_BATCH])}
    opt = adam(1e-3)

    def _time_mode(residency: str) -> tuple[float, float]:
        exe = rexec.compile(w, cfg, mesh, strategy="orrm",
                            residency=residency, kernel_mode="ref")
        state = exe.init_state(jax.random.PRNGKey(0), opt)
        step = exe.train_step(opt)
        loss = 0.0
        for _ in range(N_WARMUP):
            state, metrics = step(state, batch)
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(N_TIMED):
            state, metrics = step(state, batch)
            loss = metrics["loss"]
        jax.block_until_ready(state)
        us = 1e6 * (time.perf_counter() - t0) / N_TIMED
        return us, float(loss)

    sharded_us, sharded_loss = _time_mode("sharded")
    repl_us, repl_loss = _time_mode("replicated")
    return {
        "case": "timed_step",
        "n_devices": N_DEV,
        "sizes": list(TIMED_SIZES),
        "batch": TIMED_BATCH,
        "steps": N_TIMED,
        "sharded_step_us": sharded_us,
        "replicated_step_us": repl_us,
        "replicated_over_sharded_step": repl_us / sharded_us,
        "loss_bitmatch": bool(sharded_loss == repl_loss),
    }


def run() -> list[dict]:
    return _tracker_rows() + [_timed_row()]
