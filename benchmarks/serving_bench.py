"""Serving SLO benchmark: every traffic scenario preset through the
continuous-batching engine (``repro.serve``) on the smoke LM.

One row per scenario with the full SLO report (TTFT/TPOT/e2e p50+p99,
throughput, goodput) plus two same-box timing *ratios* the regression
gate tracks (``gate._ratio_fields``):

  tok_s_ratio     scenario throughput / steady throughput
  p99_ttft_ratio  steady p99 TTFT / scenario p99 TTFT

(steady is the anchor row at 1.0; both sides of each ratio run in the
same process on the same machine, so the ratios are comparable across
reports the way the microbench speedups are).

The device-loss-mid-decode scenario is additionally *pinned*: a no-fault
reference run of the same trace (fresh runner, same params seed, same
slot count) must produce bit-identical token streams for every request —
greedy decode is a pure function of the prompt, so a mid-decode replan +
restart may cost latency but never tokens.  ``run.py`` turns the pin and
the finished-exactly-once invariant into PASS/FAIL checks gated against
``BENCH_fcnn.json``.
"""

from __future__ import annotations

from repro.configs import smoke_config
from repro.serve import (
    JaxModelRunner,
    SCENARIO_NAMES,
    ServeAutoscaler,
    ServingEngine,
    make_traffic,
    scenario_preset,
    snap_prompt_buckets,
)

ARCH = "qwen3-14b"
SEED = 0
SLOTS = 3
# smoke-sized traffic: small bucket lists (2 prefill compiles), enough
# decode steps for the mid-decode loss to land while requests are in
# flight (preset fires at global decode step 4)
_OVERRIDES = dict(n_requests=10, prompt_buckets=(8, 16),
                  gen_buckets=(4, 8, 12))


def _run_scenario(cfg, sc, *, with_fault: bool = True):
    trace = make_traffic(sc, SEED)
    runner = JaxModelRunner(cfg, n_slots=SLOTS, max_len=sc.max_len)
    runner.warmup(sc.prompt_buckets)
    autoscaler = ServeAutoscaler(runner.n_devices, SLOTS)
    engine = ServingEngine(runner, n_slots=SLOTS, autoscaler=autoscaler)
    run_sc = sc if with_fault else sc.replace(device_loss=None)
    return engine.run(trace, run_sc), trace


def run() -> list[dict]:
    cfg = smoke_config(ARCH)
    rows: list[dict] = []
    results = {}
    for name in SCENARIO_NAMES:
        sc = scenario_preset(name, **_OVERRIDES)
        sc = sc.replace(
            prompt_buckets=snap_prompt_buckets(cfg, sc.prompt_buckets))
        result, trace = _run_scenario(cfg, sc)
        results[name] = (sc, trace, result)

    steady = results["steady"][2].slo
    for name in SCENARIO_NAMES:
        sc, trace, result = results[name]
        slo = result.slo
        submitted = set(trace.rids)
        finished_once = (set(result.streams) == submitted
                         and slo.n_finished == len(submitted))
        rows.append({
            "case": name,
            "n_requests": slo.n_submitted,
            "n_finished": slo.n_finished,
            "finished_once": finished_once,
            "n_prefills": result.n_prefills,
            "n_decode_steps": result.n_decode_steps,
            "n_restarts": slo.n_restarts,
            "replans": len(result.replans),
            "p50_ttft_s": slo.p50_ttft_s,
            "p99_ttft_s": slo.p99_ttft_s,
            "p50_tpot_s": slo.p50_tpot_s,
            "p99_tpot_s": slo.p99_tpot_s,
            "p50_e2e_s": slo.p50_e2e_s,
            "p99_e2e_s": slo.p99_e2e_s,
            "throughput_tok_s": slo.throughput_tok_s,
            "goodput_tok_s": slo.goodput_tok_s,
            "tok_s_ratio": (slo.throughput_tok_s
                            / max(steady.throughput_tok_s, 1e-9)),
            "p99_ttft_ratio": (steady.p99_ttft_s
                               / max(slo.p99_ttft_s, 1e-9)),
        })

    # device-loss pin: the same trace with the fault disabled must yield
    # identical token streams for every request
    sc, trace, faulted = results["device-loss-mid-decode"]
    reference, _ = _run_scenario(cfg, sc, with_fault=False)
    compared = sorted(set(faulted.streams) & set(reference.streams))
    match = (set(faulted.streams) == set(reference.streams)
             and all(faulted.streams[r] == reference.streams[r]
                     for r in compared))
    rows.append({
        "case": "device_loss_pin",
        "n_compared": len(compared),
        "streams_match": match,
        "replans": len(faulted.replans),
        "n_restarts": faulted.slo.n_restarts,
        "replan_reasons": [rp.reason for rp in faulted.replans],
        "lemma1_cores": [list(rp.lemma1_cores or ())
                         for rp in faulted.replans],
    })
    return rows
