"""Benchmark driver — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json PATH]

Prints one CSV block per benchmark: ``benchmark,wall_us,key=value,...``
(one line per result row), then a summary of reproduction checks.

``--json PATH`` additionally emits a machine-readable report (e.g.
``BENCH_fcnn.json``) with per-benchmark wall time, all result rows and the
reproduction checks, so the perf trajectory is tracked across PRs — the
``fcnn_kernel_microbench`` entry times the fused fwd / fwd+bwd kernel
dispatch against a plain einsum implementation, and
``softmax_xent_microbench`` does the same for the fused output-period
loss against the plain jnp log-softmax + NLL.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, "src")

# fault_injection_bench runs a real replan-resume scenario on an 8-device
# CPU ring; the flag only multiplies the *host* platform's device count, so
# it is set before any jax import and is harmless on TPU.
_HOST_FLAG = "--xla_force_host_platform_device_count"
if _HOST_FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        f"{_HOST_FLAG}=8 " + os.environ.get("XLA_FLAGS", "")).strip()

from benchmarks import (  # noqa: E402
    exec_program_bench,
    exec_residency_bench,
    fault_injection_bench,
    fcnn_kernel_microbench,
    fig7_percore_sweep,
    fig10_onoc_vs_enoc,
    program_analysis_bench,
    serving_bench,
    strategy_analysis,
    table7_prediction,
    table8_9_baselines,
    table10_optimal_cores,
    roofline_report,
)

BENCHMARKS = {
    "table7_prediction": table7_prediction.run,
    "table8_9_baselines": table8_9_baselines.run,
    "table10_optimal_cores": table10_optimal_cores.run,
    "fig7_percore_sweep": fig7_percore_sweep.run,
    "fig10_onoc_vs_enoc": fig10_onoc_vs_enoc.run,
    "strategy_analysis": strategy_analysis.run,
    "roofline_report": roofline_report.run,
    "fcnn_kernel_microbench": fcnn_kernel_microbench.run,
    "softmax_xent_microbench": fcnn_kernel_microbench.run_softmax_xent,
    "exec_program_bench": exec_program_bench.run,
    "program_analysis_bench": program_analysis_bench.run,
    "exec_residency_bench": exec_residency_bench.run,
    "fault_injection_bench": fault_injection_bench.run,
    "serving_bench": serving_bench.run,
}


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v).replace(",", ";")


def _jsonable(v):
    """Coerce numpy scalars/arrays and nested containers to JSON types."""
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if hasattr(v, "item") and getattr(v, "ndim", None) in (None, 0):
        return v.item()
    if hasattr(v, "tolist"):
        return v.tolist()
    return v


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a machine-readable report (BENCH_fcnn.json)")
    args = ap.parse_args()
    if args.only and args.only not in BENCHMARKS:
        ap.error(f"unknown benchmark {args.only!r} "
                 f"(choose from {', '.join(sorted(BENCHMARKS))})")

    checks: list[str] = []
    report: dict = {"benchmarks": {}, "checks": []}
    for name, fn in BENCHMARKS.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        rows = fn()
        us = 1e6 * (time.time() - t0)
        for row in rows:
            fields = ",".join(f"{k}={_fmt(v)}" for k, v in row.items())
            print(f"{name},{us:.0f},{fields}")
        checks.extend(_reproduction_checks(name, rows))
        report["benchmarks"][name] = {
            "wall_us": us,
            "rows": _jsonable(rows),
        }

    print("\n# reproduction checks")
    for c in checks:
        print(c)

    if args.json:
        report["checks"] = checks
        with open(args.json, "w") as f:
            json.dump(report, f, indent=1)
        print(f"\n# json report -> {args.json}")


def _reproduction_checks(name: str, rows: list[dict]) -> list[str]:
    out = []
    if name == "table7_prediction":
        refined = [r for r in rows if r["variant"] == "refined"]
        worst = max(r["ape_plateau_pct"] for r in refined)
        ok = worst <= 2.3
        out.append(f"check,table7,plateau-APE<=2.3% (paper claim): "
                   f"worst={worst:.2f}% -> {'PASS' if ok else 'FAIL'}")
        worst_apd = max(r["apd_pct"] for r in refined)
        out.append(f"check,table7,APD<=5%: worst={worst_apd:.2f}% -> "
                   f"{'PASS' if worst_apd <= 5 else 'FAIL'}")
    if name == "table8_9_baselines":
        import numpy as np
        fnp = float(np.mean([r["time_improvement_vs_fnp_pct"] for r in rows]))
        fgp = float(np.mean([r["time_improvement_vs_fgp_pct"] for r in rows]))
        out.append(f"check,table8,avg time improvement vs FNP: {fnp:.2f}% "
                   f"(paper: 22.28%)")
        out.append(f"check,table8,avg time improvement vs FGP: {fgp:.2f}% "
                   f"(paper: 4.91%)")
        ok = fnp > 0 and fgp >= 0
        out.append(f"check,table8,optimal dominates both baselines -> "
                   f"{'PASS' if ok else 'FAIL'}")
    if name == "fig10_onoc_vs_enoc":
        s = rows[-1]["summary"]
        out.append(f"check,fig10,time reduction bs64={s[64]['avg_time_reduction_pct']:.1f}% "
                   f"(paper 21.02%) bs128={s[128]['avg_time_reduction_pct']:.1f}% (paper 12.95%)")
        out.append(f"check,fig10,energy saving bs64={s[64]['avg_energy_saving_pct']:.1f}% "
                   f"(paper 47.85%) bs128={s[128]['avg_energy_saving_pct']:.1f}% (paper 39.27%)")
        ok = all(s[b]["avg_time_reduction_pct"] > 0 for b in (64, 128))
        out.append(f"check,fig10,ONoC beats ENoC at both batch sizes -> "
                   f"{'PASS' if ok else 'FAIL'}")
    if name == "strategy_analysis":
        by = {(r["wavelengths"], r["strategy"]): r for r in rows}
        ok = all(
            by[(lam, "fm")]["state_transitions"]
            <= by[(lam, "orrm")]["state_transitions"]
            <= by[(lam, "rrm")]["state_transitions"]
            for lam in (8, 64))
        out.append(f"check,table1,transition ranking FM<=ORRM<=RRM -> "
                   f"{'PASS' if ok else 'FAIL'}")
        ok = all(
            by[(lam, "fm")]["hotspot_consecutive_periods"]
            >= by[(lam, "orrm")]["hotspot_consecutive_periods"]
            for lam in (8, 64))
        out.append(f"check,thm2,FM hotspot >= ORRM hotspot -> "
                   f"{'PASS' if ok else 'FAIL'}")
    if name == "exec_program_bench":
        ok = all(r["cost_match"] for r in rows)
        out.append(f"check,exec,program cost annotations == simulate_epoch "
                   f"({len(rows)} programs, all strategies) -> "
                   f"{'PASS' if ok else 'FAIL'}")
    if name == "program_analysis_bench":
        clean = [r for r in rows if "clean" in r]
        ok = all(r["clean"] for r in clean)
        ops = sum(r["device_ops"] for r in clean)
        edges = sum(r["hb_edges"] for r in clean)
        out.append(f"check,analysis,compiled NN programs analyze clean "
                   f"({len(clean)} programs, {ops} device-ops, {edges} "
                   f"HB edges) -> {'PASS' if ok else 'FAIL'}")
        corp = next(r for r in rows if r["case"] == "corruption_corpus")
        ok = corp["corpus_ok"]
        out.append(f"check,analysis,corruption corpus passes the validator "
                   f"({corp['validator_passes']}/{corp['n_entries']}) but "
                   f"is rejected by the analyzer "
                   f"({corp['analyzer_rejects']}/{corp['n_entries']}) -> "
                   f"{'PASS' if ok else 'FAIL'}")
    if name == "exec_residency_bench":
        trs = [r for r in rows if "peak_ok" in r]
        ok = all(r["peak_ok"] and r["free_ok"] for r in trs)
        worst = max(r["peak_ratio"] for r in trs)
        out.append(f"check,residency,sharded peak <= replicated/d x1.1 and "
                   f"param FREEs drain the ledger: worst ratio "
                   f"{worst:.3f} -> {'PASS' if ok else 'FAIL'}")
        timed = next((r for r in rows if r["case"] == "timed_step"), None)
        if timed is not None:
            if timed.get("skipped"):
                out.append(f"check,residency,sharded==replicated step loss: "
                           f"skipped ({timed['reason']})")
            else:
                ok = timed["loss_bitmatch"]
                out.append(
                    f"check,residency,sharded step loss bit-matches the "
                    f"replicated oracle: step ratio "
                    f"{timed['replicated_over_sharded_step']:.2f}x -> "
                    f"{'PASS' if ok else 'FAIL'}")
    if name == "fault_injection_bench":
        pricing = [r for r in rows if "expected_s" in r]
        ok = all(r["expected_s"] >= r["degraded_s"] >= r["nominal_s"] > 0
                 for r in pricing)
        out.append(f"check,faults,expected >= degraded >= nominal epoch time "
                   f"on both backends -> {'PASS' if ok else 'FAIL'}")
        rec = next(r for r in rows if r["case"] == "device-loss-recovery")
        if rec.get("skipped"):
            out.append(f"check,faults,device-loss replan+resume: skipped "
                       f"({rec['reason']})")
        else:
            ok = rec["recovered"]
            out.append(
                f"check,faults,device-loss replan+resume matches "
                f"from-scratch run on survivors "
                f"(max loss diff {rec['max_loss_diff_vs_scratch']:.2e}) -> "
                f"{'PASS' if ok else 'FAIL'}")
    if name == "serving_bench":
        scen = [r for r in rows if "finished_once" in r]
        ok = all(r["finished_once"] for r in scen)
        total = sum(r["n_finished"] for r in scen)
        out.append(f"check,serve,every submitted request finishes exactly "
                   f"once across {len(scen)} scenario presets "
                   f"({total} requests) -> {'PASS' if ok else 'FAIL'}")
        pin = next(r for r in rows if r["case"] == "device_loss_pin")
        ok = (pin["streams_match"] and pin["replans"] >= 1
              and pin["n_restarts"] >= 1)
        out.append(f"check,serve,device-loss-mid-decode replan keeps token "
                   f"streams identical to the no-fault run "
                   f"({pin['n_compared']} streams, {pin['replans']} replans, "
                   f"{pin['n_restarts']} restarts) -> "
                   f"{'PASS' if ok else 'FAIL'}")
    if name == "fcnn_kernel_microbench":
        out.append(_microbench_check(rows, "fused fwd+bwd vs einsum"))
    if name == "softmax_xent_microbench":
        out.append(_microbench_check(rows, "fused softmax/xent fwd+bwd vs jnp"))
    return out


def _microbench_check(rows: list[dict], label: str) -> str:
    backend = rows[0]["backend"]
    worst = min(r["fwdbwd_speedup"] for r in rows)
    verdict = ("informational off-TPU" if backend != "tpu"
               else "PASS" if worst >= 1 else "FAIL")
    return (f"check,kernels,{label} on {backend}: "
            f"min speedup {worst:.2f}x ({verdict})")


if __name__ == "__main__":
    main()
