#!/usr/bin/env python
"""Repo lint (``make lint``): AST-enforced invariants pytest cannot see.

Three rules, all pure-stdlib ``ast`` (no third-party linter needed):

  deprecated-call     No calls to the deprecated execution-engine shims
                      (``repro.exec.runtime.build_train_step``, its
                      ``repro.exec`` re-export,
                      ``repro.launch.steps.build_fcnn_program_step``, and
                      the ``repro.launch.serve`` SlotManager/Request
                      shims — promoted to ``repro.serve``)
                      outside their own defining modules.  Aliased
                      imports are resolved (``import repro.exec as rexec;
                      rexec.build_train_step(...)`` is caught).  The
                      non-deprecated generic ``launch.steps
                      .build_train_step`` is distinguished by its fully
                      qualified name.  Suppress intentional uses (the
                      shims' own regression tests) with a
                      ``# lint: allow-deprecated`` comment on the line.

  np-random-in-jit    No ``numpy.random`` use inside jitted or
                      shard_map'd function bodies: host RNG silently
                      bakes one sample into the trace, a classic
                      wrong-numerics bug.  Functions count as traced
                      when decorated with ``jax.jit``/``jit`` (directly
                      or via ``functools.partial``) or passed by name to
                      ``jax.jit(...)``/``shard_map(...)``.  Suppress
                      with ``# lint: allow-np-random``.

  kernel-coverage     Every kernel module under ``src/repro/kernels/``
                      must be exercised by an oracle test: some file in
                      ``tests/`` must reference at least one of the
                      module's public functions (by name or attribute —
                      ``ops.flash_attention`` covers
                      ``kernels/flash_attention.py``), so a new Pallas
                      kernel cannot land without a test pinning it to
                      its reference implementation.

Exit status 1 when any violation is found; output is
``path:line: [rule] message`` per violation.  Used by ``make lint`` and
the CI ``lint`` job.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import sys

DEPRECATED_CALLS = {
    "repro.exec.runtime.build_train_step",
    "repro.exec.build_train_step",
    "repro.launch.steps.build_fcnn_program_step",
    "repro.launch.serve.SlotManager",
    "repro.launch.serve.Request",
}
# the shims' own modules (and the package façade re-exporting them)
DEPRECATED_HOMES = {
    os.path.join("src", "repro", "exec", "runtime.py"),
    os.path.join("src", "repro", "exec", "__init__.py"),
    os.path.join("src", "repro", "launch", "steps.py"),
    os.path.join("src", "repro", "launch", "serve.py"),
}

JIT_WRAPPERS = {"jax.jit", "jit", "jax.pmap", "pmap"}
SHARD_WRAPPERS = {"shard_map", "jax.shard_map",
                  "jax.experimental.shard_map.shard_map"}

PRAGMA_DEPRECATED = "lint: allow-deprecated"
PRAGMA_NP_RANDOM = "lint: allow-np-random"

LINT_PATHS = ("src", "tools", "tests", "benchmarks", "examples")


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


class _Aliases(ast.NodeVisitor):
    """Map local names to fully qualified import origins."""

    def __init__(self) -> None:
        self.names: dict[str, str] = {}

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.names[a.asname or a.name.split(".")[0]] = (
                a.name if a.asname else a.name.split(".")[0])

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.level:       # relative imports: not used in this repo
            return
        for a in node.names:
            self.names[a.asname or a.name] = f"{node.module}.{a.name}"


def _resolve(dotted: str, aliases: dict[str, str]) -> str:
    head, _, rest = dotted.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def _has_pragma(lines: list[str], lineno: int, pragma: str) -> bool:
    return 0 < lineno <= len(lines) and pragma in lines[lineno - 1]


# ------------------------------------------------------- deprecated-call

def _check_deprecated(tree: ast.AST, aliases: dict[str, str],
                      path: str, lines: list[str]) -> list[Violation]:
    rel = os.path.relpath(path)
    if any(rel.endswith(home) for home in DEPRECATED_HOMES):
        return []
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        fq = _resolve(dotted, aliases)
        if fq in DEPRECATED_CALLS:
            if _has_pragma(lines, node.lineno, PRAGMA_DEPRECATED):
                continue
            out.append(Violation(
                path, node.lineno, "deprecated-call",
                f"call to deprecated shim {fq} — use repro.exec.compile "
                f"(suppress intentional uses with "
                f"`# {PRAGMA_DEPRECATED}`)"))
    return out


# ------------------------------------------------------ np-random-in-jit

def _numpy_aliases(aliases: dict[str, str]) -> dict[str, str]:
    """Local names that resolve into the numpy package."""
    return {name: fq for name, fq in aliases.items()
            if fq == "numpy" or fq.startswith("numpy.")}


def _jit_roots(tree: ast.AST, aliases: dict[str, str]) -> list[ast.AST]:
    """Function defs whose bodies are traced: jit/pmap-decorated, or
    passed by name to jax.jit(...)/shard_map(...)."""
    defs: dict[str, list[ast.AST]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(node.name, []).append(node)

    def is_jit_expr(expr: ast.AST) -> bool:
        dotted = _dotted(expr)
        if dotted is not None and _resolve(dotted, aliases) in (
                JIT_WRAPPERS | {"functools.partial", "partial"}):
            return dotted not in ("functools.partial", "partial")
        return False

    roots: list[ast.AST] = []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                dotted = _dotted(target)
                fq = _resolve(dotted, aliases) if dotted else None
                if fq in JIT_WRAPPERS:
                    roots.append(node)
                elif fq in ("functools.partial", "partial") and isinstance(
                        dec, ast.Call):
                    if any(is_jit_expr(a) for a in dec.args):
                        roots.append(node)
        elif isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            fq = _resolve(dotted, aliases) if dotted else None
            if fq in (JIT_WRAPPERS | SHARD_WRAPPERS) and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Name) and arg.id in defs:
                    roots.extend(defs[arg.id])
    return roots


def _check_np_random(tree: ast.AST, aliases: dict[str, str],
                     path: str, lines: list[str]) -> list[Violation]:
    np_names = _numpy_aliases(aliases)
    if not np_names:
        return []
    out = []
    seen: set[int] = set()
    for root in _jit_roots(tree, aliases):
        for node in ast.walk(root):
            dotted = None
            if isinstance(node, (ast.Attribute, ast.Name)):
                dotted = _dotted(node)
            if dotted is None:
                continue
            fq = _resolve(dotted, np_names)
            if (fq == "numpy.random" or fq.startswith("numpy.random.")) \
                    and node.lineno not in seen:
                if _has_pragma(lines, node.lineno, PRAGMA_NP_RANDOM):
                    continue
                seen.add(node.lineno)
                out.append(Violation(
                    path, node.lineno, "np-random-in-jit",
                    f"numpy.random used inside traced function "
                    f"{getattr(root, 'name', '?')!r} — host RNG bakes one "
                    f"sample into the jitted trace; thread a jax PRNG key "
                    f"instead (suppress with `# {PRAGMA_NP_RANDOM}`)"))
    return out


# -------------------------------------------------------- kernel-coverage

def _public_functions(path: str) -> list[str]:
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    return [n.name for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            and not n.name.startswith("_")]


def check_kernel_coverage(repo_root: str = ".") -> list[Violation]:
    """Every kernels/ module must have a public symbol referenced by some
    test (oracle tests pin each kernel to its reference implementation)."""
    kdir = os.path.join(repo_root, "src", "repro", "kernels")
    tdir = os.path.join(repo_root, "tests")
    if not (os.path.isdir(kdir) and os.path.isdir(tdir)):
        return []

    referenced: set[str] = set()
    for fname in sorted(os.listdir(tdir)):
        if not fname.endswith(".py"):
            continue
        with open(os.path.join(tdir, fname)) as f:
            tree = ast.parse(f.read(), filename=fname)
        for node in ast.walk(tree):
            if isinstance(node, ast.Attribute):
                referenced.add(node.attr)
            elif isinstance(node, ast.Name):
                referenced.add(node.id)
            elif isinstance(node, ast.ImportFrom) and node.module:
                referenced.update(a.name for a in node.names)

    out = []
    for fname in sorted(os.listdir(kdir)):
        if not fname.endswith(".py") or fname == "__init__.py":
            continue
        kpath = os.path.join(kdir, fname)
        public = _public_functions(kpath)
        if public and not any(fn in referenced for fn in public):
            out.append(Violation(
                kpath, 1, "kernel-coverage",
                f"kernel module {fname} defines {public} but no test in "
                f"tests/ references any of them — add an oracle test "
                f"pinning the kernel to its reference implementation"))
    return out


# ----------------------------------------------------------------- driver

def lint_source(source: str, path: str = "<string>") -> list[Violation]:
    """Per-file rules (deprecated-call, np-random-in-jit) on one source
    string — the unit-testable core."""
    tree = ast.parse(source, filename=path)
    aliases = _Aliases()
    aliases.visit(tree)
    lines = source.splitlines()
    out = _check_deprecated(tree, aliases.names, path, lines)
    out += _check_np_random(tree, aliases.names, path, lines)
    return out


def lint_file(path: str) -> list[Violation]:
    with open(path) as f:
        source = f.read()
    try:
        return lint_source(source, path)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 1, "syntax",
                          f"could not parse: {e.msg}")]


def iter_py_files(root: str, paths=LINT_PATHS):
    for rel in paths:
        top = os.path.join(root, rel)
        if not os.path.isdir(top):
            continue
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            for fname in sorted(filenames):
                if fname.endswith(".py"):
                    yield os.path.join(dirpath, fname)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--root", default=".", help="repo root to lint")
    args = ap.parse_args(argv)

    violations: list[Violation] = []
    n_files = 0
    for path in iter_py_files(args.root):
        n_files += 1
        violations.extend(lint_file(path))
    violations.extend(check_kernel_coverage(args.root))

    for v in violations:
        print(v)
    if violations:
        print(f"lint: {len(violations)} violation(s) in {n_files} files")
        return 1
    print(f"lint: OK ({n_files} files, 3 rules)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
